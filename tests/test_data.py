import jax
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.data import DynamicsTokenStream, trajectory_tokens


def test_stream_deterministic_and_seekable():
    s = DynamicsTokenStream(vocab=128, seq_len=16, batch=4, seed=3)
    b1 = s.batch_at(10)
    b2 = s.batch_at(10)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = s.batch_at(11)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 128


@settings(max_examples=20, deadline=None)
@given(h=st.integers(2, 20), d=st.integers(1, 5), a=st.integers(1, 3),
       bins=st.sampled_from([8, 32]))
def test_trajectory_tokens_bounds(h, d, a, bins):
    key = jax.random.key(h * 100 + d)
    obs = jax.random.normal(key, (h, d)) * 3
    act = jax.random.uniform(key, (h, a), minval=-1, maxval=1)
    toks = trajectory_tokens(obs, act, bins=bins)
    assert toks.shape == (h * (d + a),)
    assert int(toks.min()) >= 0
    assert int(toks.max()) < bins * (d + a)
    # per-dimension offsets never collide
    tt = np.asarray(toks).reshape(h, d + a)
    for j in range(d + a):
        assert tt[:, j].min() >= j * bins and tt[:, j].max() < (j + 1) * bins
