"""Property-based tests: ReplayBuffer vs a pure-python FIFO ring oracle.

The oracle mirrors the documented contract transition-by-transition
(servers.ReplayBuffer): every ``1/holdout_frac``-th trajectory goes to
the val ring; a trajectory longer than its ring keeps only the LAST
``cap`` transitions; writes land at ``cursor % cap`` and wrap. Random
trajectory-length sequences then check wrap-around ordering (exact slot
layout, not just the surviving set), eviction, the val interleave
fraction, and ``size``/``total_seen`` accounting."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.servers import ReplayBuffer


class _RingOracle:
    """Plain-python FIFO ring: value v written at slot (cursor+t) % cap."""

    def __init__(self, cap):
        self.cap = cap
        self.slots = [None] * cap
        self.cursor = 0
        self.written = 0

    def write(self, values):
        values = values[-self.cap:]          # traj > cap: keep the LAST cap
        for t, v in enumerate(values):
            self.slots[(self.cursor + t) % self.cap] = v
        self.cursor = (self.cursor + len(values)) % self.cap
        self.written += len(values)

    @property
    def size(self):
        return min(self.written, self.cap)


def _check_against_oracle(lengths, cap, frac):
    rb = ReplayBuffer(cap, holdout_frac=frac)
    every = max(int(round(1 / frac)), 2) if frac > 0 else 0
    train_oracle = _RingOracle(cap)
    val_oracle = _RingOracle(rb.val_capacity)
    for i, h in enumerate(lengths):
        vals = [i * 1000.0 + t for t in range(h)]
        rb.add_traj({"obs": jnp.asarray(vals)[:, None]})
        (val_oracle if every and (i + 1) % every == 0
         else train_oracle).write(vals)

    assert rb.total_seen == len(lengths)
    assert rb.size == train_oracle.size
    assert rb.val_size == val_oracle.size
    for ring, oracle in ((rb.train_view, train_oracle),
                         (rb.val_view, val_oracle)):
        data, size = ring()
        if data is None:
            assert oracle.written == 0
            continue
        got = np.asarray(data["obs"])[:, 0]
        for slot, expect in enumerate(oracle.slots):
            if expect is not None:     # untouched slots stay alloc zeros
                assert got[slot] == expect, (
                    f"slot {slot}: got {got[slot]}, want {expect} "
                    f"(wrap-around ordering broken)")


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 9), min_size=1, max_size=25),
       st.integers(2, 12),
       st.sampled_from([0.0, 0.2, 0.5]))
def test_ring_matches_fifo_oracle(lengths, cap, frac):
    _check_against_oracle(lengths, cap, frac)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.lists(st.integers(7, 30), min_size=1,
                                   max_size=8))
def test_traj_longer_than_capacity_keeps_last_cap(cap, lengths):
    """Every trajectory here exceeds the ring: only the newest ``cap``
    transitions of the latest writes may survive."""
    _check_against_oracle(lengths, cap, 0.0)


@settings(max_examples=15, deadline=None)
@given(st.integers(5, 60), st.sampled_from([0.1, 0.2, 0.25, 0.5]))
def test_val_interleave_fraction(n_trajs, frac):
    """Exactly every ``max(round(1/frac), 2)``-th trajectory is held out."""
    rb = ReplayBuffer(1000, holdout_frac=frac)
    for i in range(n_trajs):
        rb.add_traj({"obs": jnp.full((2, 1), float(i))})
    every = max(int(round(1 / frac)), 2)
    n_val = n_trajs // every
    assert rb.val_size == min(2 * n_val, rb.val_capacity)
    assert rb.size == min(2 * (n_trajs - n_val), rb.capacity)
    assert rb.total_seen == n_trajs
