"""EMA early stopping (paper §4 / §5.4, Fig. 5a)."""
from _hypothesis_compat import given, settings, st

from repro.mbrl.early_stop import EMAEarlyStop


def test_stops_on_val_increase():
    es = EMAEarlyStop(weight=0.9)
    for v in (1.0, 0.9, 0.8):
        assert not es.update(v)
    assert es.update(5.0)          # val jumps above EMA -> stop
    assert es.stopped


def test_reset_on_new_data():
    es = EMAEarlyStop(weight=0.9)
    es.update(1.0)
    es.update(5.0)
    assert es.stopped
    es.reset()                     # new samples arrive (Alg. 2)
    assert not es.stopped
    assert not es.update(10.0)     # first loss after reset never stops


def test_disabled_never_stops():
    es = EMAEarlyStop(weight=0.9, enabled=False)
    for v in (1.0, 2.0, 4.0, 8.0):
        es.update(v)
    assert not es.stopped


@settings(max_examples=30, deadline=None)
@given(st.floats(0.05, 0.95),
       st.lists(st.floats(0.01, 10.0), min_size=2, max_size=30))
def test_monotone_decreasing_never_stops(weight, losses):
    """Property: strictly decreasing validation loss never triggers."""
    losses = sorted(losses, reverse=True)
    es = EMAEarlyStop(weight=weight)
    for v in losses:
        es.update(v)
    assert not es.stopped


@settings(max_examples=30, deadline=None)
@given(st.floats(0.05, 0.9))
def test_lower_weight_stops_sooner_or_equal(weight):
    """Property (Fig. 5a): a LOWER ema weight is at least as aggressive on
    a rebounding loss curve."""
    curve = [3.0, 2.0, 1.0, 1.2, 1.4, 1.7, 2.2, 3.0]

    def stop_index(w):
        es = EMAEarlyStop(weight=w)
        for i, v in enumerate(curve):
            if es.update(v):
                return i
        return len(curve)

    assert stop_index(weight) <= stop_index(min(weight + 0.09, 0.99))
