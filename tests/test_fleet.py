"""Collector fleets (ISSUE 5): N parallel data collectors in every
engine mode, sharing ONE global stopping criterion.

What is proven here:

* the event engine runs a deterministic fleet — per-collector
  virtual-time cursors, bit-identical traces per seed at N > 1;
* the global ``total_trajs`` criterion lands EXACTLY (ticket-claimed)
  in event and threads modes (procs: tests/test_procs.py);
* collector 0's RNG stream is the lone collector's stream, so N=1
  stays bit-identical to the pre-fleet engine and a fleet's first
  member reproduces the single-collector data;
* the paper's Fig. 4 story: at N > 1 the criterion is reached in fewer
  policy steps (parallel collection shrinks the collection span);
* per-collector exploration schedules (heterogeneous action-noise
  scales) change the collected actions without touching collector 0;
* the multi-producer drain path: ``ReplayBuffer.add_trajs`` writes a
  burst bit-identically to sequential ``add_traj`` calls, in one
  compiled scatter per chunk, compiling once across burst sizes;
* env-farm guardrails (ISSUE 6): ``envs_per_collector=1`` stays
  bit-identical to the pre-farm engine (the batched path is covered in
  tests/test_env_farm.py), and the exploration ladder round-trips
  pickling through ``ProcSpec``.
"""
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AsyncTrainer, DataServer, ReplayBuffer, RunConfig
from repro.core.servers import _ring_write_burst_impl
from repro.core.workers import ExplorationSchedule, ProcSpec, collector_key
from repro.envs import make_env
from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig, make_algo
from repro.utils.jit_stats import trace_counted


def build(env, n_models=2):
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=32,
                         n_models=n_models)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=16)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=16, imagine_horizon=15,
                      n_models=n_models)
    return ens, make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)


def _traj(i, h=8, d=3, a=1):
    k = jax.random.fold_in(jax.random.key(11), i)
    return {"obs": jax.random.normal(k, (h, d)),
            "act": jax.random.normal(jax.random.fold_in(k, 1), (h, a))}


# ------------------------------------------------------------ event engine
def test_event_fleet_deterministic_and_criterion_exact():
    """Two same-seed N=4 event runs are bit-identical; the fleet stops
    with EXACTLY total_trajs trajectories, split across members."""
    env = make_env("pendulum")
    traces = []
    for _ in range(2):
        ens, algo = build(env)
        tr = AsyncTrainer(env, ens, algo,
                          RunConfig(total_trajs=8, seed=0), n_collectors=4)
        traces.append(tr.run())
        assert tr.data_server.total_pushed == 8
        assert sum(c.collected for c in tr.collectors) == 8
        assert all(c.collected >= 1 for c in tr.collectors), \
            "every fleet member must contribute (round-robin cursors)"
    assert traces[0] == traces[1], "event fleet non-deterministic"


def test_event_fleet_fewer_policy_steps_to_criterion():
    """Fig. 4: parallel collection reaches the global criterion in less
    virtual time, hence fewer policy steps spent to get there."""
    steps, vtime = {}, {}
    env = make_env("pendulum")
    for n in (1, 4):
        ens, algo = build(env)
        tr = AsyncTrainer(env, ens, algo,
                          RunConfig(total_trajs=8, seed=0), n_collectors=n)
        trace = tr.run()
        steps[n] = tr.policy_worker.steps
        vtime[n] = trace[-1]["time"]
    assert steps[4] < steps[1], steps
    assert vtime[4] < vtime[1], vtime


def test_collector_zero_stream_matches_lone_collector():
    """Collector 0 of a fleet draws the SAME trajectories as the single
    collector of an N=1 trainer (bit-identical) — the fleet refactor
    must not perturb the pre-fleet RNG stream."""
    env = make_env("pendulum")
    ens, algo = build(env)
    tr1 = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=4, seed=5))
    ens, algo = build(env)
    tr4 = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=4, seed=5),
                       n_collectors=4)
    tr1.collector.step()
    tr4.collectors[0].step()
    (t1,), (t4,) = tr1.data_server.drain(), tr4.data_server.drain()
    for k in t1:
        np.testing.assert_array_equal(np.asarray(t1[k]), np.asarray(t4[k]))
    # other members draw DIFFERENT streams
    tr4.collectors[1].step()
    (t_b,) = tr4.data_server.drain()
    assert not np.array_equal(np.asarray(t1["obs"]), np.asarray(t_b["obs"]))


def test_collector_key_derivation():
    k = jax.random.key(3)
    assert collector_key(k, 0) is k, "collector 0 must keep the base key"
    k1, k2 = collector_key(k, 1), collector_key(k, 2)
    assert not jnp.array_equal(jax.random.key_data(k1),
                               jax.random.key_data(k2))


def test_n_collectors_validation():
    env = make_env("pendulum")
    ens, algo = build(env)
    with pytest.raises(ValueError, match="n_collectors"):
        AsyncTrainer(env, ens, algo, RunConfig(), n_collectors=0)


# ----------------------------------------------------------- threads engine
def test_threads_fleet_criterion_exact():
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=6, seed=0),
                      mode="threads", n_collectors=3)
    trace = tr.run()
    assert tr.data_server.total_pushed == 6, \
        "ticket-claimed criterion must land exactly, never overshoot"
    assert sum(c.collected for c in tr.collectors) == 6
    assert trace and trace[-1]["trajs"] == 6


def test_same_scale_fleet_shares_one_rollout_jit():
    """N same-scale members on one device must share ONE compiled
    rollout (value-keyed cache), not pay N identical trace+compiles."""
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=4, seed=0),
                      n_collectors=3)
    assert tr.collectors[0]._rollout is tr.collectors[1]._rollout \
        is tr.collectors[2]._rollout
    ens, algo = build(env)
    tr2 = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=4, seed=0),
                       n_collectors=2,
                       exploration=ExplorationSchedule((1.0, 2.0)))
    assert tr2.collectors[0]._rollout is not tr2.collectors[1]._rollout, \
        "different noise scales need different samplers"


def test_threads_collector_failure_is_loud():
    """A collector thread dying mid-run must FAIL the run (its claimed
    ticket can never be pushed), not return a short trace silently."""
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=6, seed=0),
                      mode="threads", n_collectors=2)

    def boom(p, k):
        raise RuntimeError("rollout exploded")
    # sabotage the WHOLE fleet: scheduling decides which member claims
    # first, so any single-member sabotage could be starved of tickets
    # by its healthy peer and never step at all
    for c in tr.collectors:
        c._rollout = boom
    with pytest.raises(RuntimeError, match=r"collector \d+ failed"):
        tr.run()


# ------------------------------------------------------------- exploration
def test_exploration_schedule_cycles_and_ladder():
    s = ExplorationSchedule((1.0, 0.8, 1.3))
    assert [s.scale_for(i) for i in range(5)] == [1.0, 0.8, 1.3, 1.0, 0.8]
    lad = ExplorationSchedule.ladder(4, lo=0.5, hi=1.5)
    assert lad.scale_for(0) == 1.0, "collector 0 keeps the plain policy"
    assert lad.noise_scales == (1.0, 0.5, 1.0, 1.5), \
        "varied rungs must span lo..hi evenly"
    assert ExplorationSchedule.ladder(1).noise_scales == (1.0,)
    assert ExplorationSchedule.ladder(2, lo=0.5, hi=1.5).noise_scales == \
        (1.0, 1.5), "a lone varied rung takes the hi endpoint"


def test_exploration_ladder_monotone_and_proc_spec_pickle():
    """ISSUE 6 satellite: ladder(1) is exactly the plain policy, varied
    rungs are monotone non-decreasing for every fleet size, and a
    schedule survives pickling through ProcSpec (what the spawn context
    actually ships to collector children) with scale_for intact."""
    assert ExplorationSchedule.ladder(1).noise_scales == (1.0,)
    assert ExplorationSchedule.ladder(1).scale_for(0) == 1.0
    for n in (2, 3, 4, 5, 8):
        lad = ExplorationSchedule.ladder(n, lo=0.5, hi=1.5)
        assert lad.scale_for(0) == 1.0
        varied = lad.noise_scales[1:]
        assert list(varied) == sorted(varied), \
            f"varied rungs must be monotone at n={n}: {varied}"
        assert min(varied) >= 0.5 and max(varied) <= 1.5
    lad = ExplorationSchedule.ladder(4, lo=0.25, hi=2.0)
    spec = ProcSpec(env=None, ens_cfg=None, algo_cfg=None, pol_cfg=None,
                    run_cfg=None, seed=0, exploration=lad)
    back = pickle.loads(pickle.dumps(spec)).exploration
    assert back.noise_scales == lad.noise_scales
    assert [back.scale_for(i) for i in range(8)] == \
        [lad.scale_for(i) for i in range(8)]


def test_envs_per_collector_one_is_bit_identical_to_pre_farm():
    """ISSUE 6 acceptance: an explicit B=1 farm IS the pre-farm engine —
    same single-rollout program object, bit-identical event trace."""
    from repro.core.workers import _rollout_jit
    env = make_env("pendulum")
    ens, algo = build(env)
    rc = RunConfig(total_trajs=6, seed=0)
    tr_plain = AsyncTrainer(env, ens, algo, rc)
    trace_plain = tr_plain.run()
    ens, algo = build(env)
    tr_farm = AsyncTrainer(env, ens, algo, rc, envs_per_collector=1)
    assert tr_farm.collectors[0]._rollout_batch is None, \
        "B=1 must not build a batched program"
    assert tr_farm.collectors[0]._rollout is _rollout_jit(env, 1.0), \
        "B=1 must reuse the shared single-rollout program"
    trace_farm = tr_farm.run()
    assert trace_farm == trace_plain, \
        "B=1 farm trace must be bit-identical to the pre-farm engine"


def test_exploration_noise_scale_changes_actions_only_off_rung_zero():
    """A noise-scaled collector draws different actions from the same
    policy/key; scale 1.0 is exactly the plain sampler."""
    env = make_env("pendulum")
    ens, algo = build(env)
    rc = RunConfig(total_trajs=4, seed=2)
    tr_plain = AsyncTrainer(env, ens, algo, rc)
    ens, algo = build(env)
    tr_noisy = AsyncTrainer(env, ens, algo, rc, n_collectors=2,
                            exploration=ExplorationSchedule((1.0, 2.0)))
    tr_plain.collector.step()
    tr_noisy.collectors[0].step()        # rung 0: scale 1.0
    tr_noisy.collectors[1].step()        # rung 1: scale 2.0
    (p,) = tr_plain.data_server.drain()
    a, b = tr_noisy.data_server.drain()
    np.testing.assert_array_equal(np.asarray(p["act"]), np.asarray(a["act"]))
    assert not np.array_equal(np.asarray(p["act"]), np.asarray(b["act"]))


def test_run_config_collect_noise_builds_schedule():
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo,
                      RunConfig(total_trajs=4, collect_noise=(1.0, 0.5)),
                      n_collectors=4)
    assert [c.noise_scale for c in tr.collectors] == [1.0, 0.5, 1.0, 0.5]


# ------------------------------------------------- ticket-based criterion
def test_data_server_tickets_exact_with_preexisting_pushes():
    """set_target counts trajectories already pushed (warm-up steps), so
    claims top the total up to the target exactly."""
    ds = DataServer()
    for i in range(3):
        ds.push({"x": i})
    ds.set_target(5)
    grants = sum(ds.try_claim() for _ in range(10))
    assert grants == 2, "only target - already_pushed claims may be granted"
    assert ds.try_claim() == 0


# ------------------------------------------------------- burst ring writes
def test_add_trajs_bit_identical_to_sequential_adds():
    """The fleet drain path (one padded scatter per chunk) must produce
    byte-for-byte the ring a sequential writer produces — including the
    train/val interleave, wrap-around and cursor positions."""
    seq = ReplayBuffer(40, holdout_frac=0.2)
    burst = ReplayBuffer(40, holdout_frac=0.2)
    trajs = [_traj(i) for i in range(13)]    # wraps the 40-row train ring
    for t in trajs:
        seq.add_traj(t)
    burst.add_trajs(trajs)
    for view in ("train_view", "val_view"):
        (a, na), (b, nb) = getattr(seq, view)(), getattr(burst, view)()
        assert na == nb
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))
    assert seq._cursor == burst._cursor
    assert seq._val_cursor == burst._val_cursor
    assert seq.total_seen == burst.total_seen


def test_burst_write_compiles_once_across_burst_sizes():
    """One compiled scatter covers every burst size up to the fixed
    burst_capacity (padding rows are dropped by index) — a fleet's
    variable-size drains never retrace the ring write."""
    rb = ReplayBuffer(64, holdout_frac=0.0, burst_capacity=4)
    counted = trace_counted(_ring_write_burst_impl, donate_argnums=(0,))
    rb._write_burst = counted
    rb.add_trajs([_traj(i) for i in range(2)])      # M=2
    rb.add_trajs([_traj(10 + i) for i in range(4)])  # M=4 (full burst)
    rb.add_trajs([_traj(20 + i) for i in range(3)])  # M=3
    assert counted.trace_count == 1, \
        f"burst ring write retraced {counted.trace_count - 1}x"
    assert rb.size == min(9 * 8, 64)


def test_burst_chunking_respects_capacity():
    """A burst larger than the ring keeps FIFO semantics: the last
    ``capacity`` transitions win, same as sequential writes."""
    seq = ReplayBuffer(24, holdout_frac=0.0)
    burst = ReplayBuffer(24, holdout_frac=0.0, burst_capacity=16)
    trajs = [_traj(100 + i) for i in range(9)]       # 72 rows into 24
    for t in trajs:
        seq.add_traj(t)
    burst.add_trajs(trajs)
    np.testing.assert_array_equal(
        np.asarray(seq.train_view()[0]["obs"]),
        np.asarray(burst.train_view()[0]["obs"]))
