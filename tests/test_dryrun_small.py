"""Dry-run mechanics on a small multi-device mesh.

The full 512-device production dry-run lives in repro.launch.dryrun (and
its results in dryrun_results.json); here we prove the same machinery —
shard_map lowering, compile, HLO collective parsing — on an in-process
4-device CPU mesh, AND that sharded execution is numerically identical to
the single-device path.
"""
import os
import subprocess
import sys

import pytest

# every test shells out to a 4-device subprocess that compiles a reduced
# model (internal timeout 560s each)
pytestmark = [pytest.mark.slow, pytest.mark.timeout(600)]

SELF_TEST = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import api
from repro.models.config import InputShape
from repro.optim.optimizers import adam

def mesh_of(shape, axes):
    return jax.make_mesh(shape, axes)

cfg = get_config("{arch}", reduced=True)
shape = InputShape("t", 32, 8, "train")
key = jax.random.key(0)

def batch_for(cfg):
    b = dict(tokens=jax.random.randint(key, (8, 32), 0, cfg.vocab_size))
    b["labels"] = b["tokens"]
    if cfg.family == "encdec":
        b["enc_embeds"] = jax.random.normal(key, (8, 32, cfg.d_model),
                                            jnp.bfloat16)
    if cfg.modality == "vision":
        b["patch_embeds"] = jax.random.normal(key, (8, 4, cfg.d_model),
                                              jnp.bfloat16)
    return b

results = {{}}
for ms, name in [(( 1, 1), "1x1"), ((2, 2), "2x2"), ((4, 1), "4x1"),
                 ((1, 2), "1x2")]:
    mesh = mesh_of(ms, ("data", "model"))
    b = api.build(cfg, mesh, shape)
    mod = api._mod(cfg)
    params = mod.init_params(cfg, b.ctx, key)
    opt = adam(cfg.lr); opt_state = opt.init(params)
    lowered = b.fn.lower(params, opt_state, batch_for(cfg))
    compiled = lowered.compile()          # must compile on every mesh
    p2, o2, m = b.fn(params, opt_state, batch_for(cfg))
    results[name] = float(m["loss"])
vals = list(results.values())
for v in vals[1:]:
    assert abs(v - vals[0]) < 2e-2, results   # sharding-invariant loss
print("OK", results)
"""


@pytest.mark.parametrize("arch", ["glm4_9b", "qwen3_moe_235b_a22b",
                                  "mamba2_2_7b", "zamba2_7b",
                                  "seamless_m4t_medium"])
def test_sharded_equals_unsharded(arch):
    """Loss must be invariant to the mesh factorisation (manual-TP
    correctness across data/model/both axes)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SELF_TEST.format(arch=arch)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.startswith("OK")


def test_hlo_collective_parser():
    from repro.launch.dryrun import _shape_bytes, collective_bytes
    assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert _shape_bytes("(f32[4,4], s32[2])") == 64 + 8
    hlo = """
HloModule test, entry_computation_layout={()->f32[]}

%body.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4]{0} all-reduce(%x), replica_groups={}
  ROOT %t = tuple(...)
}

%cond.1 (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main () -> f32[] {
  %w = (s32[], f32[4]) while(%init), condition=%cond.1, body=%body.1
  %ag = bf16[2,8]{1,0} all-gather(%y), dimensions={0}
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 10      # scaled by trip count
    assert out["all-gather"] == 32


def test_zero1_loss_invariant():
    """ZeRO-1 optimizer-state sharding must not change training math."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import api
from repro.models.config import InputShape
from repro.optim.optimizers import adam
cfg = get_config("glm4-9b", reduced=True)
shape = InputShape("t", 32, 8, "train")
key = jax.random.key(0)
mesh = jax.make_mesh((4, 2), ("data", "model"))
losses = {}
for z in (False, True):
    b = api.build(cfg, mesh, shape, zero1=z)
    params = api._mod(cfg).init_params(cfg, b.ctx, key)
    opt_state = adam(cfg.lr).init(params)
    batch = {"tokens": jax.random.randint(key, (8,32), 0, cfg.vocab_size)}
    batch["labels"] = batch["tokens"]
    p, o, m = b.fn(params, opt_state, batch)
    for _ in range(3):
        p, o, m = b.fn(p, o, batch)
    losses[z] = float(m["loss"])
assert abs(losses[True] - losses[False]) < 2e-2, losses
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
