"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus chunked-reference self-consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.pallas import flash_attention
from repro.kernels.gmm import ref as gmm_ref
from repro.kernels.gmm.pallas import grouped_matmul
from repro.kernels.ssd import ref as ssd_ref
from repro.kernels.ssd.pallas import ssd_chunked

KEY = jax.random.key(42)


def rand(shape, dtype, i, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape)
            * scale).astype(dtype)


# ------------------------------------------------------------ attention
ATT_CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 96, 96, 4, 4, 32, True, 0),
    (2, 256, 256, 8, 2, 64, True, 64),
    (1, 64, 64, 2, 2, 128, False, 0),
    (1, 64, 192, 4, 1, 64, True, 0),      # prefix cache (Sk > Sq)
]


@pytest.mark.parametrize("case", ATT_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_oracle(case, dtype):
    B, Sq, Sk, Hq, Hkv, D, causal, win = case
    q = rand((B, Sq, Hq, D), dtype, 1)
    k = rand((B, Sk, Hkv, D), dtype, 2)
    v = rand((B, Sk, Hkv, D), dtype, 3)
    out = flash_attention(q, k, v, causal=causal, window=win, interpret=True)
    exp = fa_ref.naive_attention(q, k, v, causal=causal, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("case", ATT_CASES[:3])
def test_chunked_ref_matches_naive(case):
    B, Sq, Sk, Hq, Hkv, D, causal, win = case
    q = rand((B, Sq, Hq, D), jnp.float32, 4)
    k = rand((B, Sk, Hkv, D), jnp.float32, 5)
    v = rand((B, Sk, Hkv, D), jnp.float32, 6)
    out = fa_ref.chunked_attention(q, k, v, causal=causal, window=win,
                                   block_q=32, block_k=64)
    exp = fa_ref.naive_attention(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-3,
                               rtol=2e-3)


def test_decode_partial_combine():
    """Sharded decode partials must combine to the unsharded answer."""
    B, S, Hq, Hkv, D = 2, 128, 4, 2, 64
    q = rand((B, Hq, D), jnp.float32, 7)
    k = rand((B, S, Hkv, D), jnp.float32, 8)
    v = rand((B, S, Hkv, D), jnp.float32, 9)
    length = 100
    o_full, _ = fa_ref.decode_attention_partial(q, k, v, length)
    outs, lses = [], []
    for sh in range(4):
        ks = k[:, sh * 32:(sh + 1) * 32]
        vs = v[:, sh * 32:(sh + 1) * 32]
        o, l = fa_ref.decode_attention_partial(q, ks, vs, length,
                                               start=sh * 32)
        outs.append(o)
        lses.append(l)
    comb = fa_ref.combine_partials(jnp.stack(outs), jnp.stack(lses))
    np.testing.assert_allclose(np.asarray(comb), np.asarray(o_full),
                               atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------------ ssd
SSD_CASES = [
    (2, 256, 4, 32, 16, 1, 64),
    (1, 100, 8, 16, 32, 2, 32),
    (2, 64, 4, 64, 64, 1, 64),
    (1, 128, 2, 32, 8, 1, 128),
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_vs_oracle(case, dtype):
    B, L, H, P, N, G, chunk = case
    x = rand((B, L, H, P), dtype, 10, 0.5)
    dt = jax.nn.softplus(rand((B, L, H), jnp.float32, 11))
    A = -jnp.exp(rand((H,), jnp.float32, 12, 0.3))
    Bm = rand((B, L, G, N), dtype, 13, 0.3)
    C = rand((B, L, G, N), dtype, 14, 0.3)
    out = ssd_chunked(x, dt, A, Bm, C, chunk=chunk, interpret=True)
    exp = ssd_ref.ssd_chunked(x, dt, A, Bm, C, chunk=chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=tol, rtol=tol)


def test_ssd_chunked_matches_sequential():
    B, L, H, P, N, G = 2, 160, 4, 16, 8, 1
    x = rand((B, L, H, P), jnp.float32, 15, 0.5)
    dt = jax.nn.softplus(rand((B, L, H), jnp.float32, 16))
    A = -jnp.exp(rand((H,), jnp.float32, 17, 0.3))
    Bm = rand((B, L, G, N), jnp.float32, 18, 0.3)
    C = rand((B, L, G, N), jnp.float32, 19, 0.3)
    for chunk in (32, 64, 160):
        out = ssd_ref.ssd_chunked(x, dt, A, Bm, C, chunk=chunk)
        exp = ssd_ref.ssd_sequential(x, dt, A, Bm, C)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=1e-3, rtol=1e-3)


def test_ssd_decode_matches_scan_tail():
    """Recurrent decode steps continue exactly from a chunked prefill."""
    B, L, H, P, N, G = 1, 96, 4, 16, 8, 1
    x = rand((B, L + 4, H, P), jnp.float32, 20, 0.5)
    dt = jax.nn.softplus(rand((B, L + 4, H), jnp.float32, 21))
    A = -jnp.exp(rand((H,), jnp.float32, 22, 0.3))
    Bm = rand((B, L + 4, G, N), jnp.float32, 23, 0.3)
    C = rand((B, L + 4, G, N), jnp.float32, 24, 0.3)
    y_full = ssd_ref.ssd_sequential(x, dt, A, Bm, C)
    y_pre, state = ssd_ref.ssd_chunked(x[:, :L], dt[:, :L], A, Bm[:, :L],
                                       C[:, :L], chunk=32,
                                       return_final_state=True)
    for t in range(L, L + 4):
        y_t, state = ssd_ref.ssd_decode_step(
            state, x[:, t], dt[:, t], A, Bm[:, t], C[:, t])
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, t]),
                                   atol=1e-3, rtol=1e-3)


# ------------------------------------------------------------------ gmm
@pytest.mark.parametrize("dims", [(4, 64, 32, 48), (2, 200, 130, 70),
                                  (8, 16, 16, 16), (1, 128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_pallas_vs_oracle(dims, dtype):
    G, M, K, N = dims
    a = rand((G, M, K), dtype, 25)
    b = rand((G, K, N), dtype, 26)
    out = grouped_matmul(a, b, interpret=True)
    exp = gmm_ref.grouped_matmul(a, b)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


# ----------------------------------------------------------- ragged gmm
RAGGED_CASES = [
    # G, M, K, N, group_sizes — empty, full, uneven, tile-straddling
    (4, 64, 32, 48, (10, 0, 54, 0)),
    (3, 200, 130, 70, (200, 0, 0)),
    (5, 37, 16, 16, (5, 8, 0, 20, 4)),
    (1, 128, 128, 128, (128,)),
    (3, 300, 96, 40, (1, 298, 1)),
]


@pytest.mark.parametrize("case", RAGGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_gmm_pallas_vs_oracle(case, dtype):
    G, M, K, N, sizes = case
    assert sum(sizes) == M
    gs = jnp.array(sizes, jnp.int32)
    a = rand((M, K), dtype, 27)
    b = rand((G, K, N), dtype, 28)
    out = grouped_matmul(a, b, gs, interpret=True)
    exp = gmm_ref.grouped_matmul(a, b, gs)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("case", RAGGED_CASES[:3])
def test_ragged_oracle_matches_per_group_numpy(case):
    """The ragged oracle itself against the plainest possible spelling:
    slice each group out and np.dot it."""
    G, M, K, N, sizes = case
    gs = jnp.array(sizes, jnp.int32)
    a = rand((M, K), jnp.float32, 29)
    b = rand((G, K, N), jnp.float32, 30)
    out = np.asarray(gmm_ref.grouped_matmul(a, b, gs))
    an, bn = np.asarray(a), np.asarray(b)
    off = 0
    for g, sz in enumerate(sizes):
        exp = an[off:off + sz] @ bn[g]
        np.testing.assert_allclose(out[off:off + sz], exp, atol=1e-4,
                                   rtol=1e-4)
        off += sz


def test_ragged_gmm_jits_with_traced_sizes():
    """group_sizes is data (bincount of sampled members) — the ragged
    path must trace with it as a dynamic operand."""
    G, M, K, N = 3, 48, 16, 24
    a = rand((M, K), jnp.float32, 31)
    b = rand((G, K, N), jnp.float32, 32)
    gs = jnp.array([20, 0, 28], jnp.int32)
    out = jax.jit(gmm_ref.grouped_matmul)(a, b, gs)
    exp = gmm_ref.grouped_matmul(a, b, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_ensemble_mlp_select_impls_agree():
    """dense (compute-all-and-select), ref (sort/ragged/unsort) and
    pallas-interpret must produce the same per-row member outputs."""
    from repro.kernels.gmm import ops as gmm_ops
    from repro.kernels.gmm import pallas as gmm_pallas
    K_, B, Din, Dh, Dout = 4, 33, 7, 24, 5
    members = {
        "w": [rand((K_, Din, Dh), jnp.float32, 33),
              rand((K_, Dh, Dout), jnp.float32, 34)],
        "b": [rand((K_, Dh), jnp.float32, 35),
              rand((K_, Dout), jnp.float32, 36)],
    }
    x = rand((B, Din), jnp.float32, 37)
    for idx in (jnp.zeros((B,), jnp.int32),               # one full group
                jnp.full((B,), K_ - 1, jnp.int32),        # last group only
                jax.random.randint(jax.random.fold_in(KEY, 38), (B,), 0,
                                   K_)):
        dense = gmm_ops.ensemble_mlp_select(members, x, idx, impl="dense")
        exp = jnp.take_along_axis(gmm_ref.ensemble_mlp(members, x),
                                  idx[None, :, None], axis=0)[0]
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(exp))
        ref_out = gmm_ops.ensemble_mlp_select(members, x, idx, impl="ref")
        np.testing.assert_allclose(np.asarray(ref_out), np.asarray(exp),
                                   atol=1e-5, rtol=1e-5)
        pk_out = gmm_pallas.ensemble_mlp_select(members, x, idx,
                                                interpret=True)
        np.testing.assert_allclose(np.asarray(pk_out), np.asarray(exp),
                                   atol=1e-4, rtol=1e-4)


def test_moe_dropless_matches_capacity_path():
    """Dropless ragged dispatch must agree with the capacity-buffer path
    when capacity is generous enough that nothing drops."""
    from repro.models import moe as MOE
    from repro.models.config import ModelConfig, ShardCtx
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                      num_experts=4, top_k=2, capacity_factor=8.0,
                      dtype="float32")
    ctx = ShardCtx()
    p = MOE.init_moe(cfg, ctx, jax.random.key(0))
    x = rand((2, 8, 16), jnp.float32, 40, 0.5)
    y_cap, aux_cap = MOE.moe_forward(cfg, ctx, p, x)   # capacity (CPU gate)
    y_drop, aux_drop = MOE.moe_forward_dropless(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_drop), np.asarray(y_cap),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(float(aux_drop), float(aux_cap), rtol=1e-5)


# ------------------------------------------------- hypothesis properties
from _hypothesis_compat import given, settings, st


@settings(max_examples=15, deadline=None)
@given(sq=st.integers(4, 80), hq=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2]), d=st.sampled_from([16, 32]))
def test_attention_causality_property(sq, hq, g, d):
    """Changing FUTURE tokens never changes past outputs (causality)."""
    hkv = max(hq // g, 1)
    hq = hkv * g
    q = rand((1, sq, hq, d), jnp.float32, sq)
    k = rand((1, sq, hkv, d), jnp.float32, sq + 1)
    v = rand((1, sq, hkv, d), jnp.float32, sq + 2)
    out1 = fa_ref.chunked_attention(q, k, v, causal=True, block_q=16,
                                    block_k=16)
    k2 = k.at[:, -1].add(10.0)
    v2 = v.at[:, -1].add(10.0)
    out2 = fa_ref.chunked_attention(q, k2, v2, causal=True, block_q=16,
                                    block_k=16)
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(l=st.integers(8, 60), h=st.sampled_from([1, 2, 4]),
       p=st.sampled_from([8, 16]), n=st.sampled_from([4, 8]))
def test_ssd_causality_property(l, h, p, n):
    x = rand((1, l, h, p), jnp.float32, l, 0.5)
    dt = jax.nn.softplus(rand((1, l, h), jnp.float32, l + 1))
    A = -jnp.exp(rand((h,), jnp.float32, l + 2, 0.3))
    Bm = rand((1, l, 1, n), jnp.float32, l + 3, 0.3)
    C = rand((1, l, 1, n), jnp.float32, l + 4, 0.3)
    y1 = ssd_ref.ssd_chunked(x, dt, A, Bm, C, chunk=16)
    x2 = x.at[:, -1].add(5.0)
    y2 = ssd_ref.ssd_chunked(x2, dt, A, Bm, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y1[:, :-1]),
                               np.asarray(y2[:, :-1]), atol=1e-5)
