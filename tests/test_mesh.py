"""Wrapper that runs the role-sharded mesh suite (tests/_mesh_impl.py)
in an ISOLATED subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

The device count locks on first backend use, so the 8-device flag can
never be set inside an already-running pytest process — other modules
must keep their single default device. The subprocess runs the whole
suite once (module-cached); the tests below then assert each of the
ISSUE-3 acceptance criteria individually against its verbose output, so
a failure points at the exact broken invariant.

Run the suite directly (faster, no double collection) with::

    make test-mesh
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

# one subprocess runs the WHOLE 8-device suite on the first test (cached
# for the rest), so the first test's cap must cover the subprocess's own
# 1500s timeout rather than the 600s per-test default
pytestmark = [pytest.mark.slow, pytest.mark.timeout(1600)]

TESTS_DIR = Path(__file__).resolve().parent
IMPL = TESTS_DIR / "_mesh_impl.py"
_CACHE = {}


def _run_suite():
    if "proc" not in _CACHE:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=8"
                            ).strip()
        src = str(TESTS_DIR.parent / "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        _CACHE["proc"] = subprocess.run(
            [sys.executable, "-m", "pytest", "-v", "--tb=short",
             "-p", "no:cacheprovider", str(IMPL)],
            capture_output=True, text=True, env=env,
            cwd=str(TESTS_DIR.parent), timeout=1500)
    return _CACHE["proc"]


def _assert_passed(name: str):
    proc = _run_suite()
    lines = [ln for ln in proc.stdout.splitlines() if f"::{name}" in ln]
    assert lines and all("PASSED" in ln for ln in lines), (
        f"{name} did not pass in the 8-device subprocess\n"
        f"--- stdout (tail) ---\n{proc.stdout[-8000:]}\n"
        f"--- stderr (tail) ---\n{proc.stderr[-2000:]}")


def test_mesh_suite_green():
    proc = _run_suite()
    assert proc.returncode == 0, (
        f"8-device mesh suite failed (rc={proc.returncode})\n"
        f"--- stdout (tail) ---\n{proc.stdout[-8000:]}\n"
        f"--- stderr (tail) ---\n{proc.stderr[-2000:]}")


def test_sharded_train_epoch_matches_single_device():
    _assert_passed("test_sharded_train_epoch_matches_single_device")


def test_sharded_imagine_rollout_matches_single_device():
    _assert_passed("test_sharded_imagine_rollout_matches_single_device")


def test_no_retrace_after_warmup_in_sharded_mode():
    _assert_passed("test_sharded_no_retrace_after_warmup")
    _assert_passed("test_sharded_imagination_no_retrace")


def test_threads_mode_role_split_completes():
    _assert_passed("test_threads_mode_role_split_completes")


def test_unchanged_pull_performs_zero_transfers():
    _assert_passed("test_pull_if_newer_cross_mesh_placement_and_no_transfer")


def test_split_roles_degenerate_meshes_fall_back_shared():
    _assert_passed("test_split_roles_degenerate_falls_back_shared")
