"""Vectorized env farm (ISSUE 6): batched rollouts make every collector
B simulated robots.

What is proven here:

* ``lane_keys``: lane 0 keeps the step key untouched (the farm-of-one
  consumes exactly the single-rollout stream), other lanes are distinct;
* ``Env.rollout_batch``: leading batch axis, deterministic, distinct
  lanes, and n=1 DELEGATES to the scalar rollout bit for bit (vmapped
  lane 0 is not guaranteed bitwise-equal to the scalar program);
* the farm worker pushes its whole batch per step, splits its key ONCE
  per step, and a PARTIAL grant g < B runs the same compiled program as
  a worker whose full batch is g — identical trajectories from
  identical keys, so the end-of-run partial batch is reproducible;
* the compiled-rollout cache is LRU-bounded (ISSUE 6 satellite) and
  clearable, and workers keep their own refs so eviction strands nothing;
* batch-aware tickets: ``try_claim(k)`` grants partial batches at the
  target edge, ``push_batch`` settles the grant and drains identically
  to sequential pushes, refunds return the exact unfilled count, and a
  denied claim backs off instead of spinning (ISSUE 6 satellite);
* the global ``total_trajs`` criterion lands EXACTLY in event and
  threads modes even when B does not divide it, with deterministic
  event traces per seed (procs: tests/test_procs.py).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AsyncTrainer, DataServer, RunConfig
from repro.core import workers as W
from repro.envs import lane_keys, make_env
from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig, make_algo


def build(env, n_models=2):
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=32,
                         n_models=n_models)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=16)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=16, imagine_horizon=15,
                      n_models=n_models)
    return ens, make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)


def tree_equal(a, b) -> bool:
    return all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _random_policy(env):
    def policy_fn(params, s, k):
        return jax.random.uniform(k, (env.act_dim,), minval=-1.0,
                                  maxval=1.0)
    return policy_fn


# ----------------------------------------------------------- lane streams
def test_lane_keys_lane0_is_key_and_lanes_distinct():
    key = jax.random.key(3)
    lanes = lane_keys(key, 4)
    assert lanes.shape == (4,)
    data = jax.random.key_data(lanes)
    np.testing.assert_array_equal(np.asarray(data[0]),
                                  np.asarray(jax.random.key_data(key)))
    rows = [tuple(np.asarray(data[i]).tolist()) for i in range(4)]
    assert len(set(rows)) == 4, "lane streams must be pairwise distinct"
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(lane_keys(key, 1))),
        np.asarray(jax.random.key_data(key))[None])


# ---------------------------------------------------------- rollout_batch
def test_rollout_batch_shapes_determinism_distinct_lanes():
    env = make_env("pendulum")
    pf = _random_policy(env)
    key = jax.random.key(0)
    batch = env.rollout_batch(key, pf, None, 3)
    H = env.horizon
    assert batch["obs"].shape == (3, H, env.obs_dim)
    assert batch["act"].shape == (3, H, env.act_dim)
    assert batch["next_obs"].shape == (3, H, env.obs_dim)
    assert batch["rew"].shape == (3, H)
    again = env.rollout_batch(key, pf, None, 3)
    assert tree_equal(batch, again), "same key must reproduce the batch"
    assert not bool(jnp.array_equal(batch["act"][0], batch["act"][1])), \
        "distinct lanes must draw distinct actions"
    assert not bool(jnp.array_equal(batch["act"][1], batch["act"][2]))


def test_rollout_batch_n1_delegates_bit_identical():
    env = make_env("pendulum")
    pf = _random_policy(env)
    key = jax.random.key(7)
    single = env.rollout(key, pf, None)
    farm1 = env.rollout_batch(key, pf, None, 1)
    for k in single:
        np.testing.assert_array_equal(np.asarray(farm1[k][0]),
                                      np.asarray(single[k]),
                                      err_msg=f"n=1 farm differs on {k}")
    with pytest.raises(ValueError, match="n >= 1"):
        env.rollout_batch(key, pf, None, 0)


# ------------------------------------------------------------ farm worker
def test_worker_batch_step_pushes_whole_batch_once():
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=6, seed=0),
                      envs_per_collector=3)
    w = tr.collectors[0]
    dur = w.step()
    assert dur == pytest.approx(env.horizon * env.dt), \
        "B robots run in parallel: one trajectory's robot time"
    assert w.collected == 3
    trajs = tr.data_server.drain()
    assert len(trajs) == 3, "the whole batch must arrive as trajectories"
    assert trajs[0]["obs"].shape == (env.horizon, env.obs_dim)
    assert not bool(jnp.array_equal(trajs[0]["act"], trajs[1]["act"]))


def test_partial_grant_shares_program_with_full_batch_of_same_size():
    """A partial batch g < B runs THE SAME compiled program object as a
    worker whose full batch is g — and produces identical trajectories
    from identical keys (the end-of-run partial batch is reproducible,
    not a differently-compiled cousin)."""
    W.clear_rollout_cache()
    env = make_env("pendulum")
    ens, algo = build(env)
    big = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=8, seed=0),
                       envs_per_collector=4).collectors[0]
    ens, algo = build(env)
    tr_small = AsyncTrainer(env, ens, algo,
                            RunConfig(total_trajs=8, seed=0),
                            envs_per_collector=2)
    small = tr_small.collectors[0]
    assert big.step(2) is not None          # partial grant through B=4
    assert small.step() is not None         # full batch of the same size
    assert small._rollout_batch is W._rollout_batch_jit(env, 1.0, 2), \
        "partial grants must hit the full-batch worker's cache entry"
    a, b = big.data_server.drain(), tr_small.data_server.drain()
    assert len(a) == len(b) == 2
    for ta, tb in zip(a, b):
        assert tree_equal(ta, tb), \
            "same key + same program must mean identical trajectories"


def test_rollout_cache_is_lru_bounded_and_clearable():
    W.clear_rollout_cache()
    env = make_env("pendulum")
    keep = W._rollout_jit(env, 1.0)
    for i in range(W._ROLLOUT_CACHE_MAX + 8):
        W._rollout_jit(env, 2.0 + i * 0.125)     # distinct cache keys
        W._rollout_jit(env, 1.0)                 # LRU touch: stays hot
    assert len(W._ROLLOUT_CACHE) <= W._ROLLOUT_CACHE_MAX
    assert W._rollout_jit(env, 1.0) is keep, \
        "a touched entry must survive eviction pressure"
    assert W._rollout_jit(env, 2.0) is not None  # oldest was evicted,
    #                                              rebuilt fresh; holders
    #                                              of the old fn are fine
    W.clear_rollout_cache()
    assert len(W._ROLLOUT_CACHE) == 0


# ------------------------------------------------- batch-aware ticketing
def test_data_server_batch_claims_partial_grants_and_refund():
    ds = DataServer()
    ds.set_target(7)
    assert ds.try_claim(0, k=4) == 4
    assert ds.try_claim(1, k=4) == 3, "partial grant at the target edge"
    assert ds.try_claim(0, k=2) == 0, "target exhausted"
    assert ds.refund_inflight(1) == 3, "refund returns the exact count"
    assert ds.refund_inflight(1) == 0, "double refund is a no-op"
    assert ds.try_claim(1, k=5) == 3, "refund reopened the slots"
    batch = {"x": np.arange(6, dtype=np.float32).reshape(3, 2)}
    ds.push_batch(batch, 3, collector_id=1)
    assert ds.total_pushed == 3
    assert ds.refund_inflight(1) == 0, "push_batch settled the grant"


def test_push_batch_drains_identically_to_sequential_pushes():
    arr = np.arange(12, dtype=np.float32).reshape(4, 3)
    ds_batch, ds_seq = DataServer(), DataServer()
    ds_batch.push_batch({"x": arr}, 4)
    for i in range(4):
        ds_seq.push({"x": arr[i]})
    a, b = ds_batch.drain(), ds_seq.drain()
    assert ds_batch.total_pushed == ds_seq.total_pushed == 4
    assert len(a) == len(b) == 4
    for ta, tb in zip(a, b):
        np.testing.assert_array_equal(ta["x"], tb["x"])
        assert ta["x"].shape == (3,), "drain yields per-traj rows"


def test_denied_claims_back_off_instead_of_spinning():
    """ISSUE 6 satellite: a collector that lost the race for the last
    tickets sleeps briefly in the denied path (outside the lock) rather
    than hammering it; granted claims pay nothing."""
    ds = DataServer(claim_backoff=0.05)
    ds.set_target(1)
    t0 = time.perf_counter()
    assert ds.try_claim(0) == 1
    assert time.perf_counter() - t0 < 0.04, "a granted claim never sleeps"
    t0 = time.perf_counter()
    assert ds.try_claim(1) == 0
    assert time.perf_counter() - t0 >= 0.045, "denial must back off"

    import multiprocessing as mp

    from repro.core.servers import ProcDataServer
    pds = ProcDataServer(mp.get_context("spawn"), n_collectors=2,
                         target=1, claim_backoff=0.05)
    t0 = time.perf_counter()
    assert pds.try_claim(0) == 1
    assert time.perf_counter() - t0 < 0.04
    t0 = time.perf_counter()
    assert pds.try_claim(1) == 0
    assert time.perf_counter() - t0 >= 0.045


# ------------------------------------------------- exact criterion, B ∤ T
def test_event_farm_exact_criterion_and_deterministic():
    """B=3 does not divide total_trajs=10: the event engine claims
    min(B, remaining) per turn, someone runs the partial variant, the
    criterion lands exactly — and the trace is bit-reproducible."""
    env = make_env("pendulum")
    traces = []
    for _ in range(2):
        ens, algo = build(env)
        tr = AsyncTrainer(env, ens, algo,
                          RunConfig(total_trajs=10, seed=0),
                          n_collectors=2, envs_per_collector=3)
        traces.append(tr.run())
        assert tr.data_server.total_pushed == 10, \
            "farm criterion must land exactly, never overshoot"
        assert sum(c.collected for c in tr.collectors) == 10
    assert traces[0] == traces[1], \
        "event farm must be deterministic per seed"


def test_threads_farm_exact_criterion_b_not_dividing():
    env = make_env("pendulum")
    ens, algo = build(env)
    rc = RunConfig(total_trajs=9, seed=0)
    tr = AsyncTrainer(env, ens, algo, rc, mode="threads",
                      n_collectors=2, envs_per_collector=4)
    trace = tr.run()
    assert tr.data_server.total_pushed == 9, \
        "threads farm criterion must land exactly with B ∤ total"
    assert sum(c.collected for c in tr.collectors) == 9
    assert trace and trace[-1]["trajs"] == 9
