import warnings

import jax
import pytest

warnings.filterwarnings("ignore", category=DeprecationWarning)


@pytest.fixture(scope="session")
def mesh1():
    """Trivial 1x1 mesh — the single-device path of the manual-TP code."""
    return jax.make_mesh((1, 1), ("data", "model"))
