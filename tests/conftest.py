import importlib.util
import signal
import warnings

import jax
import pytest

warnings.filterwarnings("ignore", category=DeprecationWarning)

# pytest-timeout provides the real per-test cap (pyproject.toml sets the
# default; @pytest.mark.timeout overrides per test). The container image
# may not ship the plugin, so a SIGALRM fallback below enforces the same
# budget — coarser (whole-second, main-thread only), but a hung
# subprocess test still fails instead of wedging the whole run.
_HAVE_TIMEOUT_PLUGIN = importlib.util.find_spec("pytest_timeout") is not None
_FALLBACK_DEFAULT_S = 600


def pytest_addoption(parser):
    if not _HAVE_TIMEOUT_PLUGIN:
        # claim the ini keys the plugin would own, so pyproject's
        # `timeout =` neither warns nor goes unenforced
        parser.addini("timeout", "per-test timeout in seconds "
                                 "(fallback implementation)")
        parser.addini("timeout_method", "ignored by the fallback "
                                        "(always SIGALRM)")


def _timeout_limit(item) -> float:
    marker = item.get_closest_marker("timeout")
    if marker is not None and marker.args:
        return float(marker.args[0])
    try:
        return float(item.config.getini("timeout") or _FALLBACK_DEFAULT_S)
    except (ValueError, TypeError):
        return _FALLBACK_DEFAULT_S


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    if _HAVE_TIMEOUT_PLUGIN or not hasattr(signal, "SIGALRM"):
        yield
        return
    limit = _timeout_limit(item)

    def _alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded the {limit:.0f}s per-test cap "
            "(conftest SIGALRM fallback; install pytest-timeout for the "
            "full implementation)")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(max(int(limit), 1))
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="session")
def mesh1():
    """Trivial 1x1 mesh — the single-device path of the manual-TP code."""
    return jax.make_mesh((1, 1), ("data", "model"))
