"""Role-sharded engine, end-to-end on a forced multi-device CPU mesh.

This module REQUIRES 8 host-platform devices and therefore must run in
its own process:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest -q tests/_mesh_impl.py        # or: make test-mesh

The filename deliberately avoids the ``test_*`` pattern so a plain
``pytest`` run never collects it in-process (the device count locks on
first backend use — forcing 8 devices here would leak into every other
module). ``tests/test_mesh.py`` is the wrapper that spawns this file in a
subprocess with the right flags, so the tier-1 suite still covers it.

What is proven here (the sharded-execution invariants, ROADMAP):

* sharded vs single-device ``train_epoch`` / ``imagine_rollout`` agree
  numerically (same math, XLA inserts the psums);
* no retrace after warmup in sharded mode (pre-sharded ring storage,
  compile-once trainers);
* a threads-mode ``AsyncTrainer`` on an (8,) mesh split (1,2,1) runs to
  completion with a sane trace;
* the unchanged ``pull_if_newer`` path performs zero transfers of any
  kind (passes ``jax.transfer_guard("disallow")``), and the changed path
  lands params on the puller's sub-mesh.
"""
import os

_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    # must happen before the first jax backend init in this process
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" {_FLAG}=8").strip()

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

if jax.device_count() < 8:
    pytest.skip("needs 8 host devices (run via make test-mesh or "
                "tests/test_mesh.py)", allow_module_level=True)

from repro.core import AsyncTrainer, RunConfig
from repro.core.roles import (batch_sharded, num_shards, replicated,
                              split_roles)
from repro.core.servers import DataServer, ParameterServer, ReplayBuffer
from repro.core.workers import ModelLearningWorker
from repro.envs import make_env
from repro.mbrl import (AlgoConfig, EnsembleConfig, PolicyConfig, dynamics
                        as DYN, make_algo)
from repro.mbrl import policy as PI
from repro.utils.jit_stats import trace_counted


def _mesh8() -> Mesh:
    return jax.make_mesh((8,), ("data",))


def _traj(i, h=8, d=3, a=1):
    k = jax.random.fold_in(jax.random.key(7), i)
    obs = jax.random.normal(k, (h, d))
    act = jax.random.normal(jax.random.fold_in(k, 1), (h, a))
    return {"obs": obs, "act": act, "next_obs": obs + 0.1 * act.sum(-1,
            keepdims=True)}


def _host(x):
    return np.asarray(jax.device_put(x, jax.devices()[0]))


# ------------------------------------------------------------ split_roles
def test_split_roles_partitions_disjoint():
    roles = split_roles(_mesh8(), ratios=(1, 2, 1))
    sizes = [m.devices.size for m in (roles.collector, roles.model,
                                      roles.policy)]
    assert sizes == [2, 4, 2]
    assert not roles.shared
    ids = [frozenset(d.id for d in m.devices.flat)
           for m in (roles.collector, roles.model, roles.policy)]
    assert len(ids[0] | ids[1] | ids[2]) == 8
    for a, b in itertools.combinations(ids, 2):
        assert not (a & b), "role sub-meshes must be disjoint"


@pytest.mark.parametrize("n", [1, 2])
def test_split_roles_degenerate_falls_back_shared(n):
    """Fewer devices than roles on the split axis: every role gets the
    FULL mesh (the rounding loop used to build an empty sub-mesh)."""
    mesh = Mesh(np.array(jax.devices()[:n]), ("data",))
    with pytest.warns(UserWarning, match="shared sub-meshes"):
        roles = split_roles(mesh, ratios=(1, 2, 1))
    assert roles.shared
    for m in (roles.collector, roles.model, roles.policy):
        assert m.devices.size == n, "shared fallback must keep the mesh"


@pytest.mark.parametrize("ratios",
                         sorted(set(itertools.permutations((1, 2, 1)))) +
                         [(1, 1, 1), (5, 1, 1), (1, 6, 1)])
@pytest.mark.parametrize("n", [3, 4, 8])
def test_split_roles_ratio_permutations_cover_mesh(n, ratios):
    mesh = Mesh(np.array(jax.devices()[:n]), ("data",))
    roles = split_roles(mesh, ratios=ratios)
    sizes = [m.devices.size for m in (roles.collector, roles.model,
                                      roles.policy)]
    assert all(s >= 1 for s in sizes), sizes
    assert sum(sizes) == n, sizes


def test_split_roles_skips_too_small_leading_axis():
    """Multi-pod shape: a (2, 4) mesh has only 2 devices on its leading
    'pod' axis — the default split must move to the 4-wide 'data' axis
    and produce a REAL partition, not the shared fallback."""
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
    roles = split_roles(mesh, ratios=(1, 2, 1))
    assert not roles.shared
    shapes = [m.devices.shape for m in (roles.collector, roles.model,
                                        roles.policy)]
    assert shapes == [(2, 1), (2, 2), (2, 1)], shapes
    # an EXPLICIT too-small axis still falls back (and warns)
    with pytest.warns(UserWarning, match="shared sub-meshes"):
        assert split_roles(mesh, ratios=(1, 2, 1), axis="pod").shared


def test_workers_shard_along_the_split_axis():
    """On a multi-axis mesh the engine must shard batches along the axis
    the split was actually carved on (roles.axis), not axis_names[0]."""
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
    env = make_env("pendulum")
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=8, n_models=2)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=8)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=16, imagine_horizon=5,
                      n_models=2)
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
    tr = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=1, seed=0),
                      mesh=mesh, role_ratios=(1, 2, 1))
    assert tr.roles.axis == "data" and not tr.roles.shared
    assert tr.model_worker._batch_shard.spec == P("data")
    assert algo._batch_sharding.spec == P("data")


def test_collector_fleet_splits_sub_mesh_across_members():
    """ISSUE 5: a fleet no longer pins every collector to device 0 of
    the collector sub-mesh — members spread round-robin across its
    devices, and each member's rollout runs where its policy cache
    lives."""
    from repro.core.roles import collector_sharding
    mesh = Mesh(np.array(jax.devices()), ("data",))
    env = make_env("pendulum")
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=8, n_models=2)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=8)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=16, imagine_horizon=5,
                      n_models=2)
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
    # (2,1,1) of 8 devices -> 4-device collector sub-mesh; 6 collectors
    # wrap round-robin: devices 0,1,2,3,0,1
    tr = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=6, seed=0),
                      mesh=mesh, role_ratios=(2, 1, 1), n_collectors=6)
    sub = tr.roles.collector
    assert sub.devices.size == 4
    sub_ids = [d.id for d in sub.devices.flat]
    placed = [next(iter(c._sharding.device_set)).id
              for c in tr.collectors]
    assert placed == sub_ids + sub_ids[:2], placed
    assert len(set(placed[:4])) == 4, \
        "first 4 fleet members must occupy 4 DISTINCT devices"
    # helper agrees with the workers' placement
    assert [next(iter(collector_sharding(sub, i).device_set)).id
            for i in range(6)] == placed
    # fleet members actually collect on their devices; criterion exact
    for c in tr.collectors:
        c.step()
        leaf = jax.tree.leaves(c._policy_cache)[0]
        assert {d.id for d in leaf.sharding.device_set} == \
            {next(iter(c._sharding.device_set)).id}
    assert tr.data_server.total_pushed == 6


# ------------------------------------------- (a) numerical equivalence
def _train_n_epochs(sharding, batch_sharding, n_epochs=4):
    cfg = EnsembleConfig(obs_dim=3, act_dim=1, hidden=16, n_models=2,
                         train_batch=16)
    key = jax.random.key(0)
    params = DYN.init_ensemble(cfg, key)
    capacity = 64
    rb = ReplayBuffer(capacity, holdout_frac=0.0, sharding=sharding)
    assert rb.capacity == capacity          # 64 already a multiple of 4
    opt, train_epoch, val_loss, update_norm = DYN.make_ring_trainer(
        cfg, rb.capacity, batch_sharding=batch_sharding)
    if sharding is not None:
        params = jax.device_put(params, replicated(sharding.mesh))
    opt_state = opt.init(params)
    for i in range(6):
        rb.add_traj(_traj(i))
    losses = []
    for e in range(n_epochs):
        data, size = rb.train_view()
        params = {**params, "norm": update_norm(data, size)}
        params, opt_state, loss = train_epoch(
            params, opt_state, data, size, jax.random.fold_in(key, e))
        losses.append(float(loss))
    data, size = rb.train_view()
    vloss = float(val_loss(params, data, size))
    return params, losses, vloss, train_epoch


def test_sharded_train_epoch_matches_single_device():
    """Data-parallel ring training over the model sub-mesh computes the
    same epochs as one device: same minibatch draws (replicated RNG),
    per-device grads psum'd by XLA."""
    roles = split_roles(_mesh8(), ratios=(1, 2, 1))
    sh = batch_sharded(roles.model)
    assert num_shards(sh) == 4
    p1, l1, v1, _ = _train_n_epochs(None, None)
    p2, l2, v2, _ = _train_n_epochs(sh, sh)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(v1, v2, rtol=2e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(_host(a), _host(b),
                                   rtol=2e-5, atol=1e-6)


def test_sharded_imagine_rollout_matches_single_device():
    """Imagination with s0 sharded over the policy sub-mesh returns the
    same trajectories as the single-device rollout (tolerance: psum
    reduction order)."""
    env = make_env("pendulum")
    cfg = EnsembleConfig(env.obs_dim, env.act_dim, hidden=16, n_models=3)
    key = jax.random.key(1)
    params = DYN.init_ensemble(cfg, key)
    pol = PI.init_policy(PolicyConfig(env.obs_dim, env.act_dim, hidden=8),
                         key)
    s0 = env.reset_batch(key, 16)
    reward_fn = jax.vmap(env.reward)
    roll = jax.jit(lambda mp, pp, s, k: DYN.imagine_rollout(
        mp, PI.sample_action, pp, s, k, 12, reward_fn))
    single = roll(params, pol, s0, jax.random.key(2))

    roles = split_roles(_mesh8(), ratios=(1, 2, 1))
    sh = batch_sharded(roles.policy)
    rp = replicated(roles.policy)
    sharded = roll(jax.device_put(params, rp), jax.device_put(pol, rp),
                   jax.device_put(s0, sh), jax.random.key(2))
    for k in ("obs", "act", "rew"):
        np.testing.assert_allclose(_host(single[k]), _host(sharded[k]),
                                   rtol=2e-5, atol=1e-6)
        assert single[k].shape == sharded[k].shape


# --------------------------------------------------- (b) no retrace
def test_sharded_no_retrace_after_warmup():
    """The sharded model worker keeps the compile-once guarantee while
    its (pre-sharded) ring fills, wraps and evicts."""
    roles = split_roles(_mesh8(), ratios=(1, 2, 1))
    cfg = EnsembleConfig(obs_dim=3, act_dim=1, hidden=16, n_models=2,
                         train_batch=16)
    ds, ms = DataServer(), ParameterServer()
    mw = ModelLearningWorker(cfg, ds, ms, jax.random.key(0), max_trajs=6,
                             early_stop=False, min_trajs=2,
                             mesh=roles.model)
    for i in range(10):                     # grows past capacity -> wraps
        ds.push(_traj(i))
        mw.step()
    assert mw.epochs >= 8
    assert mw._train_epoch.trace_count == 1, \
        f"sharded train_epoch retraced {mw._train_epoch.trace_count - 1}x"
    storage, _ = mw.buffer.train_view()
    assert all(v.sharding.is_equivalent_to(
        batch_sharded(roles.model), v.ndim) for v in storage.values()), \
        "ring storage must stay sharded across writes"


def test_sharded_imagination_no_retrace():
    """Sharded sample-then-compute rollout: one compile across fresh keys
    and updated params (the sharding constraint must not leak dynamic
    shapes)."""
    env = make_env("pendulum")
    roles = split_roles(_mesh8(), ratios=(1, 2, 1))
    sh = batch_sharded(roles.policy)
    rp = replicated(roles.policy)
    cfg = EnsembleConfig(env.obs_dim, env.act_dim, hidden=16, n_models=3)
    params = jax.device_put(DYN.init_ensemble(cfg, jax.random.key(0)), rp)
    pol = jax.device_put(
        PI.init_policy(PolicyConfig(env.obs_dim, env.act_dim, hidden=8),
                       jax.random.key(0)), rp)
    s0 = jax.device_put(env.reset_batch(jax.random.key(0), 16), sh)
    reward_fn = jax.vmap(env.reward)
    roll = trace_counted(lambda mp, pp, s, k: DYN.imagine_rollout(
        mp, PI.sample_action, pp, s, k, 10, reward_fn))
    for i in range(4):
        params = jax.tree.map(lambda x: x * 1.01, params)
        out = roll(params, pol, s0, jax.random.fold_in(jax.random.key(3),
                                                       i))
        assert bool(jnp.isfinite(out["rew"]).all())
    assert roll.trace_count == 1, \
        f"sharded imagination retraced {roll.trace_count - 1}x"


# ------------------------------------------- (c) threads-mode end-to-end
def test_threads_mode_role_split_completes():
    """Full async engine, real threads, 8-device (1,2,1) role split."""
    env = make_env("pendulum")
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=16, n_models=2)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=8)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=16, imagine_horizon=10,
                      n_models=2)
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
    tr = AsyncTrainer(env, ens, algo,
                      RunConfig(total_trajs=3, seed=0, min_warmup_trajs=2),
                      mode="threads", mesh=_mesh8(), role_ratios=(1, 2, 1))
    assert tr.roles is not None and not tr.roles.shared
    assert tr.roles.model.devices.size == 4
    trace = tr.run()
    assert tr.collector.collected >= 3
    assert trace and trace[-1]["trajs"] >= 3
    times = [r["time"] for r in trace]
    assert times == sorted(times), times
    assert all(0.0 <= t < 600.0 for t in times), times
    assert all(np.isfinite(r["eval_return"]) for r in trace)
    # params ended up on the right sub-meshes
    model_devs = {d.id for d in tr.roles.model.devices.flat}
    stored, _ = tr.model_server.pull()
    if stored is not None:
        leaf = jax.tree.leaves(stored)[0]
        assert {d.id for d in leaf.sharding.device_set} <= model_devs


# ------------------------------------- (d) zero-transfer unchanged pull
def test_pull_if_newer_cross_mesh_placement_and_no_transfer():
    roles = split_roles(_mesh8(), ratios=(1, 2, 1))
    rm, rp = replicated(roles.model), replicated(roles.policy)
    ps = ParameterServer()
    params = jax.device_put({"w": jnp.ones((32, 32)),
                             "b": jnp.zeros((32,))}, rm)
    ver = ps.push(params)
    # changed path: value re-device_put onto the puller's sub-mesh
    val, got = ps.pull_if_newer(0, sharding=rp)
    assert got == ver
    policy_devs = {d.id for d in roles.policy.devices.flat}
    for leaf in jax.tree.leaves(val):
        assert {d.id for d in leaf.sharding.device_set} == policy_devs
    # unchanged path: one lock + int compare — NO transfer of any kind
    with jax.transfer_guard("disallow"):
        for _ in range(32):
            none_val, got2 = ps.pull_if_newer(ver, sharding=rp)
            assert none_val is None and got2 == ver
    # same-placement pull skips the device_put entirely
    val2, _ = ps.pull_if_newer(0, sharding=rm)
    stored, _ = ps.pull()
    assert all(a is b for a, b in zip(jax.tree.leaves(val2),
                                      jax.tree.leaves(stored)))
