"""CPU interpret-mode parity sweep over the OPS dispatchers (ISSUE 10).

``tests/test_kernels.py`` drives the pallas modules directly; this sweep
goes through each family's ``ops`` dispatcher — the entry point the rest
of the codebase actually calls — pinning ``impl="pallas",
interpret=True`` against ``impl="ref"`` (the pure-jnp oracle) on CPU.
Runs standalone as the CI ``kernels-interpret`` step
(``JAX_PLATFORMS=cpu make test-kernels``) so kernel regressions fail
fast and separately from the full tier-1 wall.

Edge shapes covered per the oracle-first contract (docs/KERNELS.md):
empty groups, one group owning the full batch, groups straddling tile
boundaries, K=1, and B not a multiple of the block size.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.gmm import ops as gmm_ops
from repro.kernels.imag import ops as imag_ops
from repro.kernels.imag import ref as imag_ref
from repro.kernels.ssd import ops as ssd_ops

KEY = jax.random.key(7)


def rand(shape, i, scale=1.0):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape) * scale


# ------------------------------------------------------------ attention
@pytest.mark.parametrize("case", [
    # B, Sq, Sk, Hq, Hkv, D, causal, window
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 64, 192, 4, 1, 64, True, 64),     # prefix cache + sliding window
    (1, 64, 64, 2, 2, 32, False, 0),
])
def test_attention_ops_pallas_interpret_vs_ref(case):
    B, Sq, Sk, Hq, Hkv, D, causal, win = case
    q = rand((B, Sq, Hq, D), 1)
    k = rand((B, Sk, Hkv, D), 2)
    v = rand((B, Sk, Hkv, D), 3)
    out = fa_ops.attention(q, k, v, causal=causal, window=win,
                           impl="pallas", interpret=True)
    exp = fa_ref.naive_attention(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-3, rtol=2e-3)


# ------------------------------------------------------------------ ssd
@pytest.mark.parametrize("case", [
    # B, L, H, P, N, G, chunk
    (2, 256, 4, 32, 16, 1, 64),
    (1, 100, 8, 16, 32, 2, 32),           # L not a multiple of chunk
])
def test_ssd_ops_pallas_interpret_vs_ref(case):
    B, L, H, P, N, G, chunk = case
    x = rand((B, L, H, P), 10, 0.5)
    dt = jax.nn.softplus(rand((B, L, H), 11))
    A = -jnp.exp(rand((H,), 12, 0.3))
    Bm = rand((B, L, G, N), 13, 0.3)
    C = rand((B, L, G, N), 14, 0.3)
    out = ssd_ops.ssd(x, dt, A, Bm, C, chunk=chunk, impl="pallas",
                      interpret=True)
    exp = ssd_ops.ssd(x, dt, A, Bm, C, chunk=chunk, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-3, rtol=1e-3)


# ------------------------------------------------------------------ gmm
RAGGED_CASES = [
    # n_groups, M, K_dim, N, group sizes (sum = M)
    (4, 64, 32, 48, (10, 0, 54, 0)),      # empty groups
    (3, 200, 130, 70, (200, 0, 0)),       # one group owns the full batch
    (5, 37, 16, 16, (5, 8, 0, 20, 4)),    # straddling odd-size tiles
    (1, 128, 128, 128, (128,)),           # K=1
    (3, 300, 96, 40, (1, 298, 1)),
]


@pytest.mark.parametrize("case", RAGGED_CASES)
def test_gmm_ops_ragged_pallas_interpret_vs_ref(case):
    G, M, Kd, N, sizes = case
    lhs = rand((M, Kd), 20, 0.3)
    rhs = rand((G, Kd, N), 21, 0.3)
    gs = jnp.array(sizes, jnp.int32)
    out = gmm_ops.grouped_matmul(lhs, rhs, gs, impl="pallas",
                                 interpret=True)
    exp = gmm_ops.grouped_matmul(lhs, rhs, gs, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


def test_gmm_ops_select_pallas_interpret_vs_ref():
    K, B, D, H = 3, 48, 12, 32
    members = {"w": [rand((K, D, H), 22, 0.3), rand((K, H, D), 23, 0.3)],
               "b": [rand((K, H), 24, 0.1), rand((K, D), 25, 0.1)]}
    x = rand((B, D), 26)
    idx = jax.random.randint(jax.random.fold_in(KEY, 27), (B,), 0, K)
    out = gmm_ops.ensemble_mlp_select(members, x, idx, impl="pallas",
                                      interpret=True)
    exp = gmm_ops.ensemble_mlp_select(members, x, idx, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------- imag
def _imag_inputs(K, B, obs, act, hid, phid, i0=30):
    din = obs + act
    members = {"w": [rand((K, din, hid), i0, 0.3),
                     rand((K, hid, hid), i0 + 1, 0.3),
                     rand((K, hid, obs), i0 + 2, 0.3)],
               "b": [rand((K, hid), i0 + 3, 0.1),
                     rand((K, hid), i0 + 4, 0.1),
                     rand((K, obs), i0 + 5, 0.1)]}
    norm = {"mu_in": rand((din,), i0 + 6, 0.1),
            "sig_in": jnp.abs(rand((din,), i0 + 7)) + 0.5,
            "mu_out": rand((obs,), i0 + 8, 0.05),
            "sig_out": jnp.abs(rand((obs,), i0 + 9)) + 0.5}
    pol = {"w": [rand((obs, phid), i0 + 10, 0.3),
                 rand((phid, act), i0 + 11, 0.3)],
           "b": [jnp.zeros((phid,)), jnp.zeros((act,))],
           "log_std": jnp.full((act,), -0.5)}
    s = rand((B, obs), i0 + 12)
    eps = rand((B, act), i0 + 13)
    return members, norm, pol, s, eps


IMAG_CASES = [
    # K, B, obs, act, hid, phid, block_b, midx mode
    (3, 48, 3, 1, 96, 48, 128, "rand"),    # bench shape, single tile
    (3, 48, 3, 1, 96, 48, 16, "one"),      # full group + empties, tiled
    (3, 48, 3, 1, 96, 48, 16, "rand"),     # groups straddle tiles
    (1, 20, 5, 2, 32, 16, 8, "rand"),      # K=1, B not tile multiple
    (5, 37, 4, 2, 24, 12, 8, "rand"),
]


@pytest.mark.parametrize("case", IMAG_CASES)
def test_imag_ops_impls_vs_oracle(case):
    K, B, obs, act, hid, phid, bb, mode = case
    members, norm, pol, s, eps = _imag_inputs(K, B, obs, act, hid, phid)
    if mode == "one":
        midx = jnp.full((B,), min(1, K - 1), jnp.int32)
    else:
        midx = jax.random.randint(jax.random.fold_in(KEY, 50), (B,), 0, K)
    exp = imag_ref.fused_step(members, norm, pol, s, eps, midx)
    got_flat = imag_ops.fused_step(members, norm, pol, s, eps, midx,
                                   impl="fused")
    got_pal = imag_ops.fused_step(members, norm, pol, s, eps, midx,
                                  impl="pallas", interpret=True,
                                  block_b=bb)
    for got in (got_flat, got_pal):
        for e, g in zip(exp, got):
            np.testing.assert_allclose(np.asarray(g), np.asarray(e),
                                       atol=1e-4, rtol=1e-4)


def test_imag_pallas_grad_matches_ref():
    """MB-MPO differentiates THROUGH the fused step — the megakernel's
    custom_vjp must agree with grads of the oracle."""
    K, B, obs, act, hid, phid = 3, 20, 3, 1, 16, 8
    members, norm, pol, s, eps = _imag_inputs(K, B, obs, act, hid, phid,
                                              i0=60)
    midx = jax.random.randint(jax.random.fold_in(KEY, 70), (B,), 0, K)

    def loss(impl):
        def f(mem, po, ss):
            s2, a, pre = imag_ops.fused_step(mem, norm, po, ss, eps, midx,
                                             impl=impl, interpret=True,
                                             block_b=8)
            return jnp.sum(s2 ** 2) + jnp.sum(a * pre)
        return jax.grad(f, argnums=(0, 1, 2))(members, pol, s)

    g_ref = loss("ref")
    g_pal = loss("pallas")
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=1e-4, rtol=1e-4)
