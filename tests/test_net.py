"""Socket transport (PR 9) — frame codec, control plane, failure modes.

Covers the wire layer (header framing, LeafCodec / tree-frame payloads,
torn-frame rejection), the zero-array-bytes contract of unchanged gated
pulls over TCP (counter-asserted, the wire mirror of the shm zero-copy
tests), the exact-criterion ticket protocol as RPCs (claims, refunds,
backpressure), reconnect-resumes-the-global-count semantics, and the
``--transport tcp`` engines end to end: a threads run landing the
criterion exactly with N=2 collectors, and a procs run surviving a
mid-run collector SIGKILL with an exact refund under a live
InvariantMonitor. End-to-end runs are marked ``slow``.
"""
import os
import pickle
import signal
import socket
import threading
import time

import numpy as np
import pytest

from repro.net import (ControlPlane, ProtocolError, TcpParameterServer,
                       parse_addr)
from repro.net import frame as F

SEED = 0


def small_cfgs(env):
    from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=32, n_models=2)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=16)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=16, imagine_horizon=15,
                      n_models=2)
    return ens, pol, acfg


# ------------------------------------------------------------ frame layer
def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        F.send_frame(a, F.OP_PPUSH, word=-7, aux=3, flags=2,
                     payload=b"hello")
        op, word, aux, flags, payload = F.recv_frame(b)
        assert (op, word, aux, flags, payload) == \
            (F.OP_PPUSH, -7, 3, 2, b"hello")
        F.send_frame(b, F.OP_OK)        # header-only reply: 32 bytes
        assert F.recv_frame(a) == (F.OP_OK, 0, 0, 0, b"")
    finally:
        a.close()
        b.close()


def test_frame_rejects_bad_magic_and_truncation():
    a, b = socket.socketpair()
    try:
        a.sendall(b"XXXX" + b"\0" * 28)     # full header, wrong magic
        with pytest.raises(ProtocolError):
            F.recv_frame(b)
        a.close()                           # now: truncated header
        with pytest.raises(ProtocolError):
            F.recv_frame(b)
    finally:
        b.close()


def test_leaf_payload_roundtrip_incl_bf16():
    import ml_dtypes

    from repro.checkpoint.io import LeafCodec
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.asarray([1.5, -2.0], ml_dtypes.bfloat16)},
            "n": np.asarray([7, 9], np.int32)}
    codec = LeafCodec(tree)
    payload = F.encode_leaves(codec, tree)
    assert len(payload) == sum(int(n) for n in codec.nbytes)
    got = F.decode_leaves(codec, payload)
    np.testing.assert_array_equal(got["w"], tree["w"])
    assert got["b"]["c"].dtype == np.dtype(ml_dtypes.bfloat16)
    np.testing.assert_array_equal(
        got["b"]["c"].astype(np.float32), [1.5, -2.0])
    np.testing.assert_array_equal(got["n"], [7, 9])
    with pytest.raises(ProtocolError):
        F.decode_leaves(codec, payload[:-1])    # truncated payload


def test_tree_frame_roundtrip_and_truncation():
    import ml_dtypes
    tree = {"obs": np.ones((5, 3), np.float32),
            "act": np.asarray([[0.5]] * 5, ml_dtypes.bfloat16),
            "done": np.asarray([0, 0, 0, 0, 1], np.bool_)}
    payload = F.encode_tree(tree)
    got = F.decode_tree(payload)
    assert set(got) == set(tree)
    for k in tree:
        assert got[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(
            np.asarray(got[k], np.float32), np.asarray(tree[k], np.float32))
    for cut in (2, len(payload) // 2, len(payload) - 1):
        with pytest.raises(ProtocolError):
            F.decode_tree(payload[:cut])


def test_parse_addr():
    assert parse_addr("10.0.0.5:7447") == ("10.0.0.5", 7447)
    assert parse_addr(":7447") == ("0.0.0.0", 7447)


# ------------------------------------------------------- parameter stores
def test_param_push_pull_version_gating():
    with ControlPlane() as plane:
        ps = plane.parameter_server(
            "model", template={"w": np.zeros((4, 3), np.float32)})
        assert ps.pull_if_newer(0) == (None, 0)     # nothing pushed yet
        params = {"w": np.arange(12, dtype=np.float32).reshape(4, 3)}
        assert ps.push(params) == 1
        got, ver = ps.pull_if_newer(0)
        assert ver == 1
        np.testing.assert_array_equal(got["w"], params["w"])
        assert ps.push(params) == 2
        got, ver = ps.pull_if_newer(1)
        assert got is not None and ver == 2
        assert ps.pull_if_newer(2) == (None, 2)
        assert ps.version == 2
        got, ver = ps.pull()
        assert got is not None and ver == 2
        ps.close()


def test_param_unchanged_pull_moves_zero_array_bytes():
    """The wire mirror of the shm zero-copy contract: the version word
    rides the frame header, so 100 unchanged gated pulls transfer ZERO
    array payload bytes (client counter-asserted)."""
    with ControlPlane() as plane:
        ps = plane.parameter_server(
            "model", template={"w": np.zeros((128, 64), np.float32)})
        ps.push({"w": np.ones((128, 64), np.float32)})
        got, ver = ps.pull_if_newer(0)
        assert got is not None
        bytes_after_real_pull = ps.array_bytes_received
        assert bytes_after_real_pull == 128 * 64 * 4
        copies_after_real_pull = ps.copies
        for _ in range(100):
            v, _ = ps.pull_if_newer(ver)
            assert v is None
        assert ps.array_bytes_received == bytes_after_real_pull, \
            "unchanged tcp pull moved array bytes over the wire"
        assert ps.copies == copies_after_real_pull
        ps.close()


def test_param_codec_published_lazily():
    """A template-less client (threads mode / remote joiner) fetches the
    codec from the plane after someone else's first push."""
    with ControlPlane() as plane:
        writer = plane.parameter_server("policy")       # no template
        reader = plane.parameter_server("policy")       # same store id
        assert writer.store_id == reader.store_id
        writer.push({"w": np.full((3,), 2.5, np.float32)})
        got, ver = reader.pull_if_newer(0)
        assert ver == 1
        np.testing.assert_array_equal(got["w"], [2.5, 2.5, 2.5])
        writer.close()
        reader.close()


def test_torn_reply_degrades_to_cache():
    """A server that tears the reply mid-frame must NOT corrupt or crash
    a gated pull: the client degrades to its cached value, exactly like
    a seqlock reader seeing a crashed writer."""
    lst = socket.create_server(("127.0.0.1", 0))
    addr = lst.getsockname()[:2]

    def serve_one_torn_reply():
        conn, _ = lst.accept()
        try:
            F.recv_frame(conn)              # the pull request
            conn.sendall(F.MAGIC + b"\0")   # torn 5-byte header
        finally:
            conn.close()

    th = threading.Thread(target=serve_one_torn_reply, daemon=True)
    th.start()
    ps = TcpParameterServer(addr, 0, "model",
                            template={"w": np.zeros((2,), np.float32)})
    try:
        assert ps.pull_if_newer(5) == (None, 5)     # degraded, not raised
        th.join(10)
    finally:
        ps.close()
        lst.close()


def test_push_is_loud_on_dead_plane():
    plane = ControlPlane()
    ps = plane.parameter_server(
        "model", template={"w": np.zeros((2,), np.float32)})
    ps.push({"w": np.ones((2,), np.float32)})
    plane.close()
    with pytest.raises((ProtocolError, OSError)):
        ps.push({"w": np.ones((2,), np.float32)})
    ps.close()


def test_reconnect_resumes_global_state():
    """All state lives on the plane: a client that drops its connection
    (crash / network blip) redials on the next call and sees the same
    versions and the same global trajectory count."""
    with ControlPlane() as plane:
        ps = plane.parameter_server(
            "model", template={"w": np.zeros((2,), np.float32)})
        ds = plane.data_server(n_collectors=2, target=6)
        ps.push({"w": np.ones((2,), np.float32)})
        assert ds.try_claim(0, k=2) == 2
        ds.push({"x": np.ones((3,), np.float32)}, collector_id=0)
        ds.push({"x": np.ones((3,), np.float32)}, collector_id=0)
        ps.close()      # drop both sockets: next call must redial
        ds.close()
        assert ps.version == 1
        assert ds.total_pushed == 2
        assert ds.try_claim(0, k=10) == 4   # remaining toward target 6
        assert ds.refund_inflight(0) == 4
        ps.close()
        ds.close()


# ------------------------------------------------------------ data plane
def test_data_tickets_exact_and_refund():
    with ControlPlane() as plane:
        ds = plane.data_server(n_collectors=2, target=5)
        assert ds.try_claim(0, k=3) == 3
        assert ds.try_claim(1, k=3) == 2        # min(k, remaining)
        assert ds.try_claim(0, k=1) == 0        # fully claimed
        assert ds.refund_inflight(1) == 2       # died before pushing
        assert ds.refund_inflight(1) == 0       # idempotent
        assert ds.try_claim(1, k=5) == 2        # refund reopened them
        ds.push({"x": np.zeros((2,), np.float32)}, collector_id=0)
        assert ds.total_pushed == 1
        assert ds.refund_inflight(0) == 2       # 3 claimed, 1 delivered
        assert len(ds.drain()) == 1
        ds.close()


def test_data_backpressure_diagnosis():
    from repro.core.servers import BackpressureError
    with ControlPlane() as plane:
        ds = plane.data_server(n_collectors=1, maxsize=2,
                               push_timeout=0.2)
        traj = {"x": np.zeros((3,), np.float32)}
        ds.push(traj)
        ds.push(traj)
        with pytest.raises(BackpressureError) as ei:
            ds.push(traj)
        msg = str(ei.value)
        assert "2 (maxsize)" in msg
        assert "model worker" in msg
        assert "push_timeout_s" in msg
        assert len(ds.drain()) == 2             # queue intact after that
        ds.close()


def test_data_batch_push_drain_unstacks():
    with ControlPlane() as plane:
        ds = plane.data_server(n_collectors=1)
        batch = {"obs": np.stack([np.full((4, 3), i, np.float32)
                                  for i in range(3)]),
                 "rew": np.asarray([[1.0] * 4] * 3, np.float32)}
        assert ds.push_batch(batch, 3) == 3
        ds.push({"obs": np.full((4, 3), 9.0, np.float32),
                 "rew": np.ones((4,), np.float32)})
        items = ds.drain()
        assert len(items) == 4
        for i in range(3):
            assert items[i]["obs"].shape == (4, 3)
            np.testing.assert_array_equal(
                items[i]["obs"], np.full((4, 3), i, np.float32))
        np.testing.assert_array_equal(
            items[3]["obs"], np.full((4, 3), 9.0, np.float32))
        assert ds.total_pushed == 4 and len(ds) == 0
        ds.close()


def test_handles_pickle_roundtrip():
    """Handles ride ProcSpec/ProcChannels through spawn: sockets and
    locks are dropped at pickle time, the copy redials lazily."""
    with ControlPlane() as plane:
        ps = plane.parameter_server(
            "model", template={"w": np.zeros((2,), np.float32)})
        ds = plane.data_server(n_collectors=1, target=3)
        ps.push({"w": np.ones((2,), np.float32)})
        ps2 = pickle.loads(pickle.dumps(ps))
        ds2 = pickle.loads(pickle.dumps(ds))
        assert ps2.version == 1
        got, ver = ps2.pull_if_newer(0)
        assert ver == 1 and got is not None
        assert ds2.try_claim(0, k=5) == 3
        assert ds2.refund_inflight(0) == 3
        for h in (ps, ds, ps2, ds2):
            h.close()


def test_join_tickets_allocate_fresh_ids():
    from repro.net.join import request_join_ticket
    with ControlPlane() as plane:
        plane.parameter_server(
            "model", template={"w": np.zeros((2,), np.float32)})
        plane.parameter_server(
            "policy", template={"w": np.zeros((2,), np.float32)})
        ds = plane.data_server(n_collectors=2, target=8,
                               push_timeout=12.5)
        plane.set_join_spec(pickle.dumps({"fake": "spec"}))
        t1 = request_join_ticket(plane.connect_addr)
        t2 = request_join_ticket(plane.connect_addr)
        # joiner ids start past the local fleet and increment
        assert (t1["collector_id"], t2["collector_id"]) == (2, 3)
        assert t1["stores"] == {"model": 0, "policy": 1}
        assert t1["n_collectors"] == 2
        assert t1["push_timeout"] == 12.5
        assert pickle.loads(t1["spec"]) == {"fake": "spec"}
        # joiner ids claim from the SAME ticket counters
        assert ds.try_claim(t1["collector_id"], k=3) == 3
        assert ds.refund_inflight(t1["collector_id"]) == 3
        ds.close()


def test_event_mode_rejects_tcp():
    import jax

    from repro.core import AsyncTrainer, RunConfig
    from repro.envs import make_env
    from repro.mbrl import make_algo
    env = make_env("pendulum")
    ens, pol, acfg = small_cfgs(env)
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
    with pytest.raises(ValueError, match="real engine"):
        AsyncTrainer(env, ens, algo,
                     RunConfig(total_trajs=4, seed=SEED, transport="tcp"))


# --------------------------------------------- crash exactness (spawn)
def _tcp_farm_producer(ds, cid, batch, start_evt, hang_evt=None):
    """Module-level so the spawn context can pickle it. Mirrors
    tests/test_procs._farm_producer over the socket transport: claims up
    to ``batch`` tickets per step, pushes the granted batch whole; with
    ``hang_evt`` it delivers ONE lane, then hangs holding the rest —
    the mid-batch crash shape."""
    start_evt.wait(30)
    while True:
        g = ds.try_claim(cid, k=batch)
        if not g:
            break
        if hang_evt is not None:
            ds.push({"x": np.full((3,), cid, np.float32)},
                    collector_id=cid)
            hang_evt.set()
            time.sleep(300)      # SIGKILLed here, holding g - 1 tickets
        ds.push_batch({"x": np.full((g, 3), cid, np.float32)}, g,
                      collector_id=cid)


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_tcp_multi_producer_exact_under_mid_batch_kill():
    """The acceptance crash shape over TCP: a remote producer process
    SIGKILLed mid-batch (1 of 3 claimed lanes delivered) leaves exactly
    its unfilled lanes refundable, and replacements land the global
    criterion EXACTLY. Unlike the mp queue there is no feeder-lock
    hazard to dodge: the plane reads whole frames, so a kill mid-send
    just drops that connection and touches no shared state."""
    import multiprocessing as mp
    ctx = mp.get_context("spawn")
    target = 10
    with ControlPlane() as plane:
        ds = plane.data_server(n_collectors=3, target=target)
        start = ctx.Event()
        hang = ctx.Event()
        victim = ctx.Process(target=_tcp_farm_producer,
                             args=(ds, 2, 3, start, hang), daemon=True)
        victim.start()
        start.set()
        assert hang.wait(60), "victim never reached its hang point"
        os.kill(victim.pid, signal.SIGKILL)
        victim.join(30)
        assert victim.exitcode != 0
        assert ds.total_pushed == 1
        assert ds.refund_inflight(2) == 2, \
            "mid-batch kill must leave exactly the unfilled lanes"
        procs = [ctx.Process(target=_tcp_farm_producer,
                             args=(ds, cid, 3, start), daemon=True)
                 for cid in range(3)]
        for p in procs:
            p.start()
        for p in procs:
            p.join(120)
            assert p.exitcode == 0, "producer crashed"
        assert ds.total_pushed == target, \
            f"global count not exact: {ds.total_pushed} != {target}"
        drained = []
        deadline = time.monotonic() + 30
        while len(drained) < target and time.monotonic() < deadline:
            drained.extend(ds.drain())
            time.sleep(0.01)
        assert len(drained) == target
        assert ds.try_claim(0) == 0, "tickets must stay exhausted"
        ds.close()


# --------------------------------------------------------- end to end
@pytest.mark.slow
@pytest.mark.timeout(900)
def test_threads_tcp_fleet_lands_criterion_exact():
    """threads + --transport tcp: two collectors share the one global
    criterion through the control plane and land it EXACTLY; the
    trainer snapshots net_info before closing its plane."""
    import jax

    from repro.core import AsyncTrainer, RunConfig
    from repro.envs import make_env
    from repro.mbrl import make_algo
    env = make_env("pendulum")
    ens, pol, acfg = small_cfgs(env)
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
    # paced so the learners share the run; the EXACT criterion is the
    # deterministic assertion (threads mode stops on trajectories alone
    # — min_final_* gates are a procs-mode contract, so the version
    # counts here are informational, not asserted)
    rc = RunConfig(total_trajs=6, seed=SEED, min_warmup_trajs=2,
                   n_collectors=2, transport="tcp",
                   collect_speed=50.0, pace_collection=True)
    tr = AsyncTrainer(env, ens, algo, rc, mode="threads")
    trace = tr.run()
    assert tr.net_info["trajs"] == rc.total_trajs, tr.net_info
    assert tr.net_info["model_version"] >= 0
    assert trace and trace[-1]["trajs"] >= rc.total_trajs


@pytest.mark.slow
@pytest.mark.timeout(900)
def test_procs_tcp_collector_sigkill_exact_with_monitor(tmp_path):
    """The PR 9 acceptance run: procs + --transport tcp with N=2
    collectors, one SIGKILLed mid-run. The parent refunds exactly its
    unfilled lanes and restarts it; the run lands the criterion EXACTLY
    and a live InvariantMonitor (PR 7) sees monotone versions, the
    exact criterion, and bounded restarts across the reconnect — zero
    violations."""
    from repro.chaos.monitor import InvariantMonitor
    from repro.core import AsyncTrainer, RunConfig
    from repro.envs import make_env
    env = make_env("pendulum")
    ens, pol, acfg = small_cfgs(env)
    rc = RunConfig(total_trajs=9, seed=SEED, min_warmup_trajs=2,
                   eval_every_policy_steps=2, snapshot_every_s=1.0,
                   pace_collection=True, collect_speed=2.0,
                   ckpt_dir=str(tmp_path / "ckpt"),
                   transport="tcp", n_collectors=2,
                   min_final_model_version=1, min_final_policy_version=1)
    monitor = InvariantMonitor()
    tr = AsyncTrainer(env, ens, None, rc, mode="procs",
                      algo_cfg=acfg, pol_cfg=pol, supervisor=monitor)
    done = {}
    th = threading.Thread(target=lambda: done.setdefault("t", tr.run()),
                          daemon=True)
    th.start()
    killed = False
    deadline = time.monotonic() + 600
    while th.is_alive() and not killed and time.monotonic() < deadline:
        srv = getattr(tr, "_proc_servers", None)
        procs = getattr(tr, "_procs", None)
        if srv and procs and "collector:1" in procs:
            try:
                pushed = srv["data"].total_pushed
            except (ProtocolError, OSError):
                pushed = 0
            p = procs["collector:1"]
            if pushed >= 2 and p.is_alive():
                os.kill(p.pid, signal.SIGKILL)
                killed = True
        time.sleep(0.02)
    assert killed, "never got a live collector to kill"
    th.join(600)
    assert not th.is_alive(), "procs+tcp run wedged after the kill"
    assert tr.proc_info["trajs"] == rc.total_trajs, \
        f"criterion not exact over tcp: {tr.proc_info['trajs']}"
    assert tr.proc_info["restarts"]["collector:1"] >= 1
    assert monitor.report()["violations"] == []
