"""Process-isolated engine (mode="procs") — ISSUE 4 acceptance tests.

Covers the IPC servers (shared-memory parameter store + trajectory
queue), the spawn-based engine end-to-end against a same-seed threads
run, the counter-instrumented zero-copy contract of unchanged pulls
(in-process AND from a separate process), and checkpoint-based crash
restart of the model worker.

The end-to-end runs are marked ``slow`` (they spawn three jax processes
that each compile their step functions) and carry generous per-test
timeouts so a wedged child can never hang CI. See tests/README.md.
"""
import os
import pickle
import signal
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AsyncTrainer, RunConfig
from repro.core.servers import ProcDataServer, ShmParameterServer
from repro.envs import make_env
from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig, make_algo

SEED = 0


def small_cfgs(env):
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=32, n_models=2)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=16)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=16, imagine_horizon=15,
                      n_models=2)
    return ens, pol, acfg


def all_finite(tree) -> bool:
    return all(bool(np.isfinite(np.asarray(x)).all())
               for x in jax.tree.leaves(tree))


# ------------------------------------------------------- shm param server
def test_shm_roundtrip_and_version_gating():
    tmpl = {"a": np.zeros((4, 3), np.float32),
            "b": {"c": np.zeros((2,), np.int32)}}
    srv = ShmParameterServer(tmpl)
    try:
        assert srv.pull_if_newer(0) == (None, 0)
        params = {"a": jnp.arange(12, dtype=jnp.float32).reshape(4, 3),
                  "b": {"c": jnp.array([7, 9], jnp.int32)}}
        assert srv.push(params) == 1
        got, ver = srv.pull_if_newer(0)
        assert ver == 1
        np.testing.assert_array_equal(got["a"],
                                      np.arange(12).reshape(4, 3))
        np.testing.assert_array_equal(got["b"]["c"], [7, 9])
        # push bumps the version; a second pull at current version gates
        srv.push(params)
        v, ver = srv.pull_if_newer(1)
        assert v is not None and ver == 2
        assert srv.pull_if_newer(2) == (None, 2)
    finally:
        srv.close()


def test_shm_unchanged_pull_is_zero_copy():
    """The PR 1 contract, counter-instrumented: an unchanged-version pull
    performs ZERO array copies (one 8-byte version read only)."""
    srv = ShmParameterServer({"w": np.zeros((128, 64), np.float32)})
    try:
        srv.push({"w": jnp.ones((128, 64), jnp.float32)})
        got, ver = srv.pull_if_newer(0)
        assert got is not None
        copies_after_real_pull = srv.copies
        assert copies_after_real_pull >= 1
        for _ in range(200):
            v, _ = srv.pull_if_newer(ver)
            assert v is None
        assert srv.copies == copies_after_real_pull, \
            "unchanged-version pull copied arrays"
    finally:
        srv.close()


def test_shm_exotic_dtypes_roundtrip():
    """bf16 leaves ride the same storable-view codec as checkpoints."""
    import ml_dtypes
    srv = ShmParameterServer({"w": np.zeros((3,), ml_dtypes.bfloat16)})
    try:
        srv.push({"w": jnp.asarray([1.5, -2.0, 3.25], jnp.bfloat16)})
        got, _ = srv.pull()
        assert got["w"].dtype == np.dtype(ml_dtypes.bfloat16)
        np.testing.assert_array_equal(got["w"].astype(np.float32),
                                      [1.5, -2.0, 3.25])
    finally:
        srv.close()


def test_shm_cross_process_pull_zero_copy(tmp_path):
    """A SEPARATE process attaches by name, sees the pushed value, and
    its unchanged pulls copy nothing (client-side counter)."""
    srv = ShmParameterServer({"w": np.zeros((8, 8), np.float64)})
    try:
        srv.push({"w": np.full((8, 8), 3.0)})
        handle = tmp_path / "handle.pkl"
        handle.write_bytes(pickle.dumps(srv))
        code = (
            "import pickle, sys\n"
            f"h = pickle.loads(open({str(handle)!r}, 'rb').read())\n"
            "v, ver = h.pull_if_newer(0)\n"
            "assert ver == 1 and float(v['w'].sum()) == 192.0\n"
            "c0 = h.copies\n"
            "for _ in range(100):\n"
            "    x, _ = h.pull_if_newer(ver)\n"
            "    assert x is None\n"
            "print('COPIES', h.copies - c0)\n")
        env = dict(os.environ)
        env["PYTHONPATH"] = (
            str(os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "src"))
            + os.pathsep + env.get("PYTHONPATH", ""))
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=120)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "COPIES 0" in r.stdout, r.stdout
    finally:
        srv.close()


def test_shm_reader_survives_writer_crash_mid_push():
    """Sequence word stuck odd (writer died mid-copy): readers degrade to
    their cache instead of hanging, and the next good push recovers."""
    srv = ShmParameterServer({"w": np.zeros((4,), np.float32)})
    try:
        srv.push({"w": np.ones((4,), np.float32)})
        _, ver = srv.pull_if_newer(0)
        # simulate a writer killed mid-push: odd sequence word
        srv._write_word(0, srv._read_word(0) + 1)
        srv._write_word(8, ver + 1)      # version already bumped
        v, got_ver = srv.pull_if_newer(ver)
        assert v is None and got_ver == ver, "reader must degrade, not spin"
        # restarted writer's push re-synchronises the seqlock
        srv.push({"w": np.full((4,), 2.0, np.float32)})
        v, _ = srv.pull_if_newer(ver)
        assert v is not None and float(v["w"][0]) == 2.0
    finally:
        srv.close()


def test_proc_data_server_push_drain():
    import multiprocessing as mp
    ds = ProcDataServer(mp.get_context("spawn"))
    assert ds.drain() == [] and ds.total_pushed == 0
    for i in range(3):
        ds.push({"obs": np.full((5, 2), i, np.float32)})
    assert ds.total_pushed == 3
    deadline = time.monotonic() + 10     # queue feeder thread latency
    items = []
    while len(items) < 3 and time.monotonic() < deadline:
        items.extend(ds.drain())
    assert [int(t["obs"][0, 0]) for t in items] == [0, 1, 2]
    assert ds.drain() == []
    assert ds.total_pushed == 3          # drain moves, doesn't recount


def test_proc_data_server_backpressure_error():
    """A full queue must surface a DESCRIPTIVE error (queue size, the
    slowest consumer, the knob to turn) after the configured timeout —
    not a bare queue.Full after a hard-coded 30 s."""
    import multiprocessing as mp

    from repro.core.servers import BackpressureError
    ds = ProcDataServer(mp.get_context("spawn"), maxsize=2,
                        push_timeout=0.2)
    traj = {"obs": np.zeros((4, 2), np.float32)}
    ds.push(traj)
    ds.push(traj)
    t0 = time.monotonic()
    with pytest.raises(BackpressureError) as ei:
        ds.push(traj, collector_id=1)
    assert time.monotonic() - t0 < 5.0, "constructor timeout not honored"
    msg = str(ei.value)
    assert "2 (maxsize)" in msg and "model worker" in msg \
        and "push_timeout_s" in msg, msg
    # per-call override still works
    with pytest.raises(BackpressureError):
        ds.push(traj, timeout=0.05)
    assert ds.total_pushed == 2, "a failed push must not count"


def test_proc_data_server_tickets_and_refund():
    """Ticket accounting behind the exact fleet criterion: claims stop
    at the target, an in-flight crash is refundable exactly once."""
    import multiprocessing as mp
    ds = ProcDataServer(mp.get_context("spawn"), n_collectors=2, target=3)
    assert ds.try_claim(0) == 1 and ds.try_claim(1) == 1
    assert ds.try_claim(0) == 1
    assert ds.try_claim(1) == 0, "claims must stop at the target"
    # collector 0 'crashed' between claims and pushes: BOTH of its
    # in-flight tickets come back in one refund, exactly once
    assert ds.refund_inflight(0) == 2
    assert ds.refund_inflight(0) == 0, "double refund must be a no-op"
    assert ds.try_claim(1) == 1
    ds.push({"x": np.zeros(1, np.float32)}, collector_id=1)
    assert ds.refund_inflight(1) == 1, \
        "one of collector 1's two tickets is still unfilled"
    assert ds.refund_inflight(1) == 0


def test_proc_data_server_batch_claims_and_push():
    """ISSUE 6 farm accounting: try_claim(k) grants partial batches at
    the end of the target, push_batch settles the whole grant in one
    queue item, and drain unpacks it into per-trajectory dicts."""
    import multiprocessing as mp
    ds = ProcDataServer(mp.get_context("spawn"), n_collectors=2, target=7)
    assert ds.try_claim(0, k=4) == 4
    assert ds.try_claim(1, k=4) == 3, "partial grant at the target edge"
    assert ds.try_claim(0, k=4) == 0, "target exhausted"
    batch = {"x": np.arange(8, dtype=np.float32).reshape(4, 2)}
    ds.push_batch(batch, 4, collector_id=0)
    assert ds.total_pushed == 4
    assert ds.refund_inflight(0) == 0, "push_batch settled the grant"
    assert ds.refund_inflight(1) == 3
    got = []
    deadline = time.monotonic() + 30
    while len(got) < 4 and time.monotonic() < deadline:
        got.extend(ds.drain())
        time.sleep(0.01)
    assert [float(t["x"][0]) for t in got] == [0.0, 2.0, 4.0, 6.0]
    assert got[0]["x"].shape == (2,), "drain yields per-traj rows"


def _fleet_producer(ds, cid, n_items, start_evt, hang_evt=None):
    """Module-level so the spawn context can pickle it (tests dir rides
    sys.path into the child)."""
    start_evt.wait(30)
    pushed = 0
    while ds.try_claim(cid):
        if hang_evt is not None and pushed == n_items:
            hang_evt.set()
            time.sleep(300)      # SIGKILLed here, holding a ticket
        ds.push({"x": np.full((3,), cid, np.float32)}, collector_id=cid)
        pushed += 1


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_proc_data_server_multi_producer_exact_under_kill():
    """ISSUE 5 satellite: the shared total stays exact with >= 3
    concurrent producer PROCESSES, and across a SIGKILL + restart of
    one producer (the parent refunds its in-flight ticket, a
    replacement resumes the GLOBAL count)."""
    import multiprocessing as mp
    ctx = mp.get_context("spawn")
    target = 24
    ds = ProcDataServer(ctx, n_collectors=3, target=target)
    start = ctx.Event()
    hang = ctx.Event()
    # producer 2 pushes 2 items, then hangs while HOLDING a ticket
    # (daemon=True everywhere: a failing assertion must never wedge the
    # pytest process at exit joining a stuck child)
    victim = ctx.Process(target=_fleet_producer, args=(ds, 2, 2, start,
                                                       hang), daemon=True)
    victim.start()
    start.set()
    assert hang.wait(60), "victim never reached its hang point"
    # drain the victim's two items BEFORE killing it: once the parent
    # has received them, the victim's queue feeder thread is provably
    # idle, so SIGKILL cannot land mid-pipe-write holding the queue's
    # shared writer lock (which would wedge every other producer — the
    # documented transactional-queue limitation, not what this test
    # is about)
    drained = []
    deadline = time.monotonic() + 30
    while len(drained) < 2 and time.monotonic() < deadline:
        drained.extend(ds.drain())
        time.sleep(0.01)
    assert len(drained) == 2, "victim's pushes never arrived"
    os.kill(victim.pid, signal.SIGKILL)
    victim.join(30)
    assert victim.exitcode != 0
    assert ds.total_pushed == 2
    assert ds.refund_inflight(2) == 1, \
        "killed-mid-claim producer must leave a refundable ticket"
    # 3 fresh concurrent producers (incl. the victim's replacement)
    # race for the remaining tickets
    procs = [ctx.Process(target=_fleet_producer,
                         args=(ds, cid, 0, start), daemon=True)
             for cid in range(3)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(120)
        assert p.exitcode == 0, "producer crashed"
    deadline = time.monotonic() + 30
    while len(drained) < target and time.monotonic() < deadline:
        drained.extend(ds.drain())
        time.sleep(0.01)
    assert ds.total_pushed == target, \
        f"global count not exact: {ds.total_pushed} != {target}"
    assert len(drained) == target, len(drained)
    assert ds.try_claim(0) == 0, "tickets must stay exhausted"


def _farm_producer(ds, cid, batch, start_evt, hang_evt=None):
    """Batched producer (module-level for spawn pickling): claims up to
    ``batch`` tickets per step and pushes the granted batch whole. With
    ``hang_evt`` it pushes ONE lane of its first grant, then hangs still
    holding the rest — the mid-batch crash shape."""
    start_evt.wait(30)
    while True:
        g = ds.try_claim(cid, k=batch)
        if not g:
            break
        if hang_evt is not None:
            ds.push({"x": np.full((3,), cid, np.float32)},
                    collector_id=cid)
            hang_evt.set()
            time.sleep(300)      # SIGKILLed here, holding g - 1 tickets
        ds.push_batch({"x": np.full((g, 3), cid, np.float32)}, g,
                      collector_id=cid)


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_proc_data_server_exact_under_mid_batch_kill():
    """ISSUE 6 acceptance: a farm collector SIGKILLed MID-BATCH (one
    lane pushed, the rest of its grant in flight) leaves exactly the
    unfilled remainder refundable, and the global criterion still lands
    exactly even though the batch size does not divide the target."""
    import multiprocessing as mp
    ctx = mp.get_context("spawn")
    target, batch = 8, 3                      # 3 does not divide 8
    ds = ProcDataServer(ctx, n_collectors=2, target=target)
    start = ctx.Event()
    hang = ctx.Event()
    victim = ctx.Process(target=_farm_producer,
                         args=(ds, 0, batch, start, hang), daemon=True)
    victim.start()
    start.set()
    assert hang.wait(60), "victim never reached its hang point"
    drained = []
    deadline = time.monotonic() + 60
    while len(drained) < 1 and time.monotonic() < deadline:
        drained.extend(ds.drain())
        time.sleep(0.01)
    assert len(drained) == 1, "victim's single lane never arrived"
    os.kill(victim.pid, signal.SIGKILL)
    victim.join(30)
    assert ds.total_pushed == 1
    assert ds.refund_inflight(0) == batch - 1, \
        "the unfilled remainder of the batch must come back"
    # a replacement farm races the surviving slot for the remaining 7
    procs = [ctx.Process(target=_farm_producer,
                         args=(ds, cid, batch, start), daemon=True)
             for cid in range(2)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(120)
    deadline = time.monotonic() + 60
    while len(drained) < target and time.monotonic() < deadline:
        drained.extend(ds.drain())
        time.sleep(0.01)
    assert ds.total_pushed == target, \
        f"global count not exact: {ds.total_pushed} != {target}"
    assert len(drained) == target, len(drained)
    assert ds.try_claim(0, k=batch) == 0, "tickets must stay exhausted"


def test_procs_mode_requires_plain_configs():
    env = make_env("pendulum")
    ens, pol, acfg = small_cfgs(env)
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
    with pytest.raises(ValueError, match="algo_cfg"):
        AsyncTrainer(env, ens, algo, RunConfig(), mode="procs")
    with pytest.raises(ValueError, match="mesh"):
        AsyncTrainer(env, ens, algo, RunConfig(), mode="procs",
                     algo_cfg=acfg, pol_cfg=pol,
                     mesh=jax.make_mesh((1,), ("data",)))


# --------------------------------------------------------- end-to-end runs
@pytest.mark.slow
@pytest.mark.timeout(900)
def test_procs_and_threads_runs_same_seed_both_train(tmp_path):
    """ISSUE 4 acceptance: a small-config procs run and a threads run
    from the same seed both complete and produce valid trained params
    (finite, version past the warmup push)."""
    env = make_env("pendulum")
    ens, pol, acfg = small_cfgs(env)
    rc = RunConfig(total_trajs=6, seed=SEED, min_warmup_trajs=2,
                   eval_every_policy_steps=2, snapshot_every_s=1.0,
                   ckpt_dir=str(tmp_path / "ckpt"),
                   min_final_model_version=1, min_final_policy_version=3)
    tr = AsyncTrainer(env, ens, None, rc, mode="procs",
                      algo_cfg=acfg, pol_cfg=pol)
    trace = tr.run()
    assert tr.proc_info["trajs"] >= rc.total_trajs
    assert tr.proc_info["model_version"] >= 1, "model never trained"
    assert tr.proc_info["policy_version"] > 1, \
        "policy version never moved past the warmup init push"
    assert tr.proc_info["restarts"] == {"collector:0": 0, "model": 0,
                                        "policy": 0}
    assert all_finite(tr.policy_worker.state["policy"])
    assert all_finite(tr.model_worker.params)
    assert trace, "procs run recorded no eval rows"
    times = [r["time"] for r in trace]
    assert times == sorted(times) and trace[-1]["trajs"] >= rc.total_trajs

    # same seed, same configs, threads engine
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
    rc_t = RunConfig(total_trajs=6, seed=SEED, min_warmup_trajs=2,
                     eval_every_policy_steps=2)
    tr_t = AsyncTrainer(env, ens, algo, rc_t, mode="threads")
    trace_t = tr_t.run()
    assert trace_t and trace_t[-1]["trajs"] >= rc_t.total_trajs
    assert tr_t.policy_server.version >= 1
    assert all_finite(tr_t.policy_worker.state["policy"])


@pytest.mark.slow
@pytest.mark.timeout(900)
def test_procs_fleet_of_four_completes_criterion_exact(tmp_path):
    """ISSUE 5 acceptance (+ ISSUE 6 farm): AsyncTrainer(n_collectors=4,
    envs_per_collector=2) in procs mode — four farm-collector processes
    plus model/policy — completes with the global trajectory criterion
    landing EXACTLY even though the batch size does not divide it
    (someone runs the partial-batch variant), per-collector restart
    accounting in place, and a heterogeneous exploration ladder."""
    env = make_env("pendulum")
    ens, pol, acfg = small_cfgs(env)
    rc = RunConfig(total_trajs=9, seed=SEED, min_warmup_trajs=2,
                   eval_every_policy_steps=2, snapshot_every_s=2.0,
                   ckpt_dir=str(tmp_path / "ckpt"),
                   collect_noise=(1.0, 0.75, 1.25, 1.5),
                   envs_per_collector=2,
                   min_final_model_version=1, min_final_policy_version=2)
    tr = AsyncTrainer(env, ens, None, rc, mode="procs",
                      algo_cfg=acfg, pol_cfg=pol, n_collectors=4)
    trace = tr.run()
    assert tr.proc_info["trajs"] == rc.total_trajs, \
        f"fleet criterion not exact: {tr.proc_info['trajs']}"
    assert tr.proc_info["n_collectors"] == 4
    assert tr.proc_info["noise_scales"] == [1.0, 0.75, 1.25, 1.5]
    assert set(tr.proc_info["restarts"]) == \
        {"model", "policy", "collector:0", "collector:1", "collector:2",
         "collector:3"}
    assert tr.proc_info["model_version"] >= 1
    assert all_finite(tr.policy_worker.state["policy"])
    assert trace and trace[-1]["trajs"] == rc.total_trajs


@pytest.mark.slow
@pytest.mark.timeout(900)
def test_procs_model_worker_killed_restarts_from_snapshot(tmp_path):
    """Kill the model-worker child mid-run: the trainer restarts it from
    the latest snapshot and the run completes with a NEWER model version
    than at kill time."""
    env = make_env("pendulum")
    ens, pol, acfg = small_cfgs(env)
    rc = RunConfig(total_trajs=10, seed=SEED, min_warmup_trajs=2,
                   eval_every_policy_steps=2, snapshot_every_s=0.5,
                   pace_collection=True, collect_speed=2.0,
                   ckpt_dir=str(tmp_path / "ckpt"),
                   min_final_model_version=1, min_final_policy_version=3)
    tr = AsyncTrainer(env, ens, None, rc, mode="procs",
                      algo_cfg=acfg, pol_cfg=pol)
    out = {}

    def run():
        out["trace"] = tr.run()

    th = threading.Thread(target=run, daemon=True)
    th.start()
    # wait until the model worker has published AND been snapshotted
    from repro.checkpoint.io import latest_step
    deadline = time.monotonic() + 600
    while time.monotonic() < deadline:
        srv = getattr(tr, "_proc_servers", None)
        if srv and srv["model"].version >= 1 \
                and latest_step(rc.ckpt_dir) is not None:
            break
        time.sleep(0.1)
    else:
        pytest.fail("model worker never published a version to snapshot")
    kill_version = tr._proc_servers["model"].version
    os.kill(tr._procs["model"].pid, signal.SIGKILL)
    th.join(timeout=700)
    assert not th.is_alive(), "run wedged after killing the model worker"
    assert tr.proc_info["restarts"]["model"] >= 1, \
        "supervisor recorded no model-worker restart"
    assert tr.proc_info["model_version"] > kill_version, \
        (tr.proc_info["model_version"], kill_version)
    assert tr.proc_info["trajs"] >= rc.total_trajs
    assert all_finite(tr.policy_worker.state["policy"])
    assert all_finite(tr.model_worker.params)
    assert out["trace"], "no eval trace after restart"


@pytest.mark.slow
@pytest.mark.timeout(900)
def test_procs_collector_kills_past_budget_fail_loudly(tmp_path):
    """SIGKILL the same fleet collector ``max_restarts + 1`` times: the
    supervisor must fail the run with a RuntimeError naming the role and
    its per-role budget — never hang, never complete quietly (ISSUE 7).
    The second collector keeps the run alive between kills, proving the
    budget really is per-role."""
    env = make_env("pendulum")
    ens, pol, acfg = small_cfgs(env)
    rc = RunConfig(total_trajs=30, seed=SEED, min_warmup_trajs=2,
                   pace_collection=True, collect_speed=2.0,
                   snapshot_every_s=0.5, max_restarts=1,
                   ckpt_dir=str(tmp_path / "ckpt"))
    tr = AsyncTrainer(env, ens, None, rc, mode="procs",
                      algo_cfg=acfg, pol_cfg=pol, n_collectors=2)
    out = {}

    def run():
        try:
            out["trace"] = tr.run()
            out["error"] = None
        except Exception as e:  # noqa: BLE001 — the error IS the assertion
            out["error"] = e

    th = threading.Thread(target=run, daemon=True)
    th.start()
    # kill every incarnation of collector:0 as it comes up (original +
    # each respawn) until the budget of max_restarts=1 is exceeded
    kills, seen = 0, set()
    deadline = time.monotonic() + 600
    while kills < rc.max_restarts + 1 and time.monotonic() < deadline:
        if not th.is_alive():
            break
        procs = getattr(tr, "_procs", None)
        p = procs.get("collector:0") if procs else None
        if p is not None and p.pid not in seen and p.exitcode is None:
            seen.add(p.pid)
            try:
                os.kill(p.pid, signal.SIGKILL)
                kills += 1
            except ProcessLookupError:
                pass
        time.sleep(0.05)
    assert kills == rc.max_restarts + 1, f"only delivered {kills} kills"
    th.join(timeout=700)
    assert not th.is_alive(), \
        "run wedged instead of failing the exhausted restart budget"
    err = out["error"]
    assert isinstance(err, RuntimeError), f"expected RuntimeError, got {err!r}"
    assert "collector:0" in str(err), str(err)
    assert "max_restarts=1" in str(err), str(err)
    assert tr.proc_info["restarts"]["collector:0"] == rc.max_restarts + 1
    # the failure tore the fleet down: no child outlives the run
    assert all(p.exitcode is not None for p in tr._procs.values())
