"""Hot-path invariants: retrace-free ring-buffer training and
version-gated, copy-free parameter pulls (ISSUE 1 tentpole).

The two invariants under test (also tracked by benchmarks/hotpath.py):
* NO RETRACE AFTER WARMUP — the ring trainer's ``train_epoch`` compiles
  exactly once no matter how the buffer fills (the seed re-concatenated
  the buffer each epoch, retracing on every data refresh);
* NO HOST COPY ON UNCHANGED VERSION — ``pull_if_newer`` with a current
  version returns immediately without touching the stored pytree.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.servers import DataServer, ParameterServer, ReplayBuffer
from repro.mbrl import dynamics as DYN


def _traj(i, h=4, d=2, a=1):
    return {"obs": jnp.full((h, d), float(i)),
            "act": jnp.full((h, a), float(i)),
            "next_obs": jnp.full((h, d), float(i) + 0.5)}


# ------------------------------------------------------------ ReplayBuffer
def test_ring_static_shapes_and_growth():
    rb = ReplayBuffer(capacity=20, holdout_frac=0.0)
    shapes = set()
    for i in range(9):
        rb.add_traj(_traj(i))
        data, size = rb.train_view()
        shapes.add(tuple(v.shape for v in data.values()))
        assert size == min((i + 1) * 4, 20)
    assert len(shapes) == 1, "storage shapes must never change"


def test_ring_fifo_eviction():
    rb = ReplayBuffer(capacity=12, holdout_frac=0.0)   # 3 trajs of h=4
    for i in range(7):
        rb.add_traj(_traj(i))
    data, size = rb.train_view()
    assert size == 12
    kept = sorted(set(np.asarray(data["obs"])[:, 0].tolist()))
    assert kept == [4.0, 5.0, 6.0], "oldest trajectories must be evicted"
    assert rb.total_seen == 7


def test_ring_val_split():
    rb = ReplayBuffer(capacity=40, holdout_frac=0.2)
    for i in range(10):
        rb.add_traj(_traj(i))
    vdata, vsize = rb.val_view()
    assert vsize > 0
    assert rb.val_size <= rb.val_capacity
    vals = set(np.asarray(vdata["obs"])[:vsize, 0].tolist())
    tdata, tsize = rb.train_view()
    trains = set(np.asarray(tdata["obs"])[:tsize, 0].tolist())
    assert vals.isdisjoint(trains), "held-out trajs must not be trained on"


def test_ring_traj_longer_than_capacity_keeps_newest():
    """A trajectory longer than its ring must not scatter with duplicate
    indices (undefined write order) — it keeps the LAST cap transitions."""
    rb = ReplayBuffer(capacity=3, holdout_frac=0.0)
    rb.add_traj({"obs": jnp.arange(8.0)[:, None]})
    data, size = rb.train_view()
    assert size == 3
    kept = sorted(np.asarray(data["obs"])[:, 0].tolist())
    assert kept == [5.0, 6.0, 7.0]
    # val ring smaller than the horizon: same guarantee
    rb2 = ReplayBuffer(capacity=8, holdout_frac=0.2)   # val_capacity = 2
    for i in range(5):
        rb2.add_traj(_traj(i))                         # traj #5 -> val
    vdata, vsize = rb2.val_view()
    assert vsize == 2 == rb2.val_capacity


def test_ring_no_holdout():
    rb = ReplayBuffer(capacity=8, holdout_frac=0.0)
    for i in range(2):
        rb.add_traj(_traj(i))
    assert rb.val_size == 0


# ----------------------------------------------------- retrace regression
def test_train_epoch_compiles_exactly_once_across_fills():
    """Seed behavior: one XLA retrace per buffer growth. Ring trainer:
    exactly one compile, ever."""
    cfg = DYN.EnsembleConfig(obs_dim=2, act_dim=1, hidden=8, n_models=2,
                             train_batch=16)
    capacity = 64
    rb = ReplayBuffer(capacity, holdout_frac=0.0)
    key = jax.random.key(0)
    params = DYN.init_ensemble(cfg, key)
    opt, train_epoch, val_loss, update_norm = DYN.make_ring_trainer(
        cfg, capacity)
    opt_state = opt.init(params)
    assert train_epoch.trace_count == 0
    for i in range(12):                       # buffer grows, wraps, evicts
        rb.add_traj(_traj(i, h=4))
        data, size = rb.train_view()
        params = {**params, "norm": update_norm(data, size)}
        params, opt_state, loss = train_epoch(
            params, opt_state, data, size, jax.random.fold_in(key, i))
        assert jnp.isfinite(loss)
    assert train_epoch.trace_count == 1, \
        f"train_epoch retraced {train_epoch.trace_count - 1} times"
    assert val_loss.trace_count <= 1
    assert update_norm.trace_count == 1


def test_masked_loss_ignores_invalid_rows():
    cfg = DYN.EnsembleConfig(obs_dim=2, act_dim=1, hidden=8, n_models=2)
    params = DYN.init_ensemble(cfg, jax.random.key(0))
    obs = jax.random.normal(jax.random.key(1), (8, 2))
    act = jax.random.normal(jax.random.key(2), (8, 1))
    nobs = obs + 0.1
    w_half = jnp.arange(8) < 4
    garbage = obs.at[4:].set(1e6)   # invalid region filled with junk
    l_clean = DYN.masked_mse_loss(params, obs, act, nobs, w_half)
    l_junk = DYN.masked_mse_loss(params, garbage, act,
                                 nobs.at[4:].set(-1e6), w_half)
    np.testing.assert_allclose(float(l_clean), float(l_junk), rtol=1e-6)


# ------------------------------------------------- imagination hot path
def test_imagination_jit_no_retrace():
    """The sample-then-compute rollout (sorting by traced member draws,
    ragged group sizes from bincount) must not leak dynamic shapes: one
    compile across fresh keys and updated model/policy params."""
    from repro.envs import make_env
    from repro.mbrl import policy as PI
    from repro.mbrl.algos import _rollout_with_logp
    from repro.utils.jit_stats import trace_counted

    env = make_env("pendulum")
    cfg = DYN.EnsembleConfig(env.obs_dim, env.act_dim, hidden=16,
                             n_models=3)
    key = jax.random.key(0)
    params = DYN.init_ensemble(cfg, key)
    pol = PI.init_policy(PI.PolicyConfig(env.obs_dim, env.act_dim,
                                         hidden=8), key)
    s0 = env.reset_batch(key, 8)
    roll = trace_counted(lambda mp, pp, s, k: _rollout_with_logp(
        mp, pp, s, k, 10, jax.vmap(env.reward)))
    for i in range(4):
        params = jax.tree.map(lambda x: x * 1.01, params)
        obs, pre, rew = roll(params, pol, s0, jax.random.fold_in(key, i))
        assert jnp.isfinite(rew).all()
    assert roll.trace_count == 1, \
        f"imagination retraced {roll.trace_count - 1} times"


def test_imagination_never_evaluates_all_k_members(monkeypatch):
    """Hot-loop guard (ISSUE 10): the legacy compute-all-then-select
    ``DYN.predict`` / ``DYN.ensemble_forward`` pair is still importable,
    but imagination must never route through it.

    Two teeth: (a) trace-based — tracing the fused rollout with the
    compute-all entry points instrumented records ZERO calls; (b)
    FLOP-based — the assigned ragged forward (``ensemble_mlp_select``,
    the path the Pallas megakernel implements on TPU) compiles to well
    under half the FLOPs of the all-K ``ensemble_mlp`` at K=8."""
    from repro.envs import make_env
    from repro.mbrl import policy as PI
    from repro.mbrl.algos import _rollout_with_logp

    env = make_env("pendulum")
    cfg = DYN.EnsembleConfig(env.obs_dim, env.act_dim, hidden=16,
                             n_models=3)
    key = jax.random.key(0)
    params = DYN.init_ensemble(cfg, key)
    pol = PI.init_policy(PI.PolicyConfig(env.obs_dim, env.act_dim,
                                         hidden=8), key)
    s0 = env.reset_batch(key, 8)

    calls = []
    monkeypatch.setattr(DYN, "predict",
                        lambda *a, **k: calls.append("predict"))
    monkeypatch.setattr(DYN, "ensemble_forward",
                        lambda *a, **k: calls.append("ensemble_forward"))
    jax.eval_shape(lambda mp, pp, s, k: _rollout_with_logp(
        mp, pp, s, k, 10, jax.vmap(env.reward)), params, pol, s0, key)
    jax.eval_shape(lambda mp, pp, s, k: DYN.imagine_rollout(
        mp, PI.sample_action, pp, s, k, 10, jax.vmap(env.reward)),
        params, pol, s0, key)
    assert not calls, f"imagination hit the compute-all path: {calls}"

    from repro.kernels.gmm import ops as gmm_ops
    K, B, D, H = 8, 64, 24, 48
    members = {
        "w": [jnp.ones((K, D, H)), jnp.ones((K, H, D))],
        "b": [jnp.zeros((K, H)), jnp.zeros((K, D))],
    }
    x = jnp.ones((B, D))
    idx = jnp.zeros((B,), jnp.int32)

    def flops(fn, *args):
        cost = jax.jit(fn).lower(*args).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        return cost["flops"]

    all_k = flops(lambda m, v: gmm_ops.ensemble_mlp(m, v), members, x)
    assigned = flops(lambda m, v, i: gmm_ops.ensemble_mlp_select(
        m, v, i, impl="ref"), members, x, idx)
    assert assigned < all_k / 2, (assigned, all_k)


# --------------------------------------------------------- ParameterServer
def test_pull_if_newer_semantics():
    ps = ParameterServer()
    v, ver = ps.pull_if_newer(0)
    assert v is None and ver == 0            # nothing pushed yet
    ps.push({"w": jnp.ones(3)})
    v, ver = ps.pull_if_newer(0)
    assert v is not None and ver == 1
    v2, ver2 = ps.pull_if_newer(ver)
    assert v2 is None and ver2 == 1          # unchanged: no value returned
    ps.push({"w": jnp.zeros(3)})
    v3, ver3 = ps.pull_if_newer(ver)
    assert ver3 == 2 and np.allclose(np.asarray(v3["w"]), 0)


def test_pull_if_newer_returns_same_object_no_copy():
    """The changed-version path hands back the stored reference; the
    unchanged path must not touch the pytree at all."""
    ps = ParameterServer()
    ps.push({"w": jnp.ones(3)})
    stored, ver = ps.pull()
    again, _ = ps.pull_if_newer(0)
    assert all(a is b for a, b in zip(jax.tree.leaves(stored),
                                      jax.tree.leaves(again)))


def test_push_isolates_from_donated_buffers():
    """push snapshots device-side: mutating/invalidating the pushed
    pytree's buffers later must not corrupt the stored version."""
    ps = ParameterServer()
    src = {"w": jnp.ones(3)}
    ps.push(src)
    src["w"].delete()                        # simulate donation reuse
    val, _ = ps.pull()
    np.testing.assert_allclose(np.asarray(val["w"]), 1.0)


def test_pull_host_materializes_numpy():
    ps = ParameterServer()
    assert ps.pull_host() == (None, 0)
    ps.push({"w": jnp.full((2,), 3.0)})
    host, ver = ps.pull_host()
    assert isinstance(host["w"], np.ndarray) and ver == 1


def test_pull_if_newer_under_concurrent_push():
    """Version gating never goes backwards or tears under racing pushes."""
    ps = ParameterServer({"w": jnp.zeros(4)})
    stop = threading.Event()
    errors = []

    def pusher(v):
        for _ in range(50):
            ps.push({"w": jnp.full(4, float(v))})

    def gated_puller():
        ver = 0
        while not stop.is_set():
            val, new_ver = ps.pull_if_newer(ver)
            if new_ver < ver:
                errors.append(("version went backwards", ver, new_ver))
            if val is not None:
                arr = np.asarray(val["w"])
                if not np.all(arr == arr[0]):
                    errors.append(("torn read", arr))
            ver = new_ver

    threads = [threading.Thread(target=pusher, args=(i,)) for i in range(3)]
    pt = threading.Thread(target=gated_puller)
    pt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    pt.join()
    assert not errors, errors
    assert ps.version == 151


# ------------------------------------------------------------- integration
def test_model_worker_never_retraces_and_gated_pulls():
    """End-to-end: grow data across many worker epochs; the trainer must
    compile once and unchanged pulls must return None."""
    from repro.core.workers import ModelLearningWorker
    cfg = DYN.EnsembleConfig(obs_dim=2, act_dim=1, hidden=8, n_models=2,
                             train_batch=16)
    ds, ms = DataServer(), ParameterServer()
    mw = ModelLearningWorker(cfg, ds, ms, jax.random.key(0),
                             max_trajs=8, early_stop=False, min_trajs=2)
    for i in range(10):
        ds.push(_traj(i, h=4))
        mw.step()
    assert mw.epochs >= 8
    assert mw._train_epoch.trace_count == 1
    # consumer sees versions advance; unchanged pull is a no-op
    val, ver = ms.pull_if_newer(0)
    assert val is not None and ver == mw.epochs
    assert ms.pull_if_newer(ver) == (None, ver)
