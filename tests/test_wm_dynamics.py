"""Transformer world-model dynamics: learning + Dyna integration."""
import jax
import jax.numpy as jnp
import pytest

from repro.envs import make_env
from repro.mbrl import policy as PI
from repro.mbrl.algos import AlgoConfig, MEAlgo
from repro.mbrl.policy import PolicyConfig
from repro.mbrl.wm_dynamics import WMConfig, WorldModelDynamics


@pytest.fixture(scope="module")
def trained_wm():
    env = make_env("pendulum")
    key = jax.random.key(0)
    wm = WorldModelDynamics(WMConfig(env.obs_dim, env.act_dim, bins=21,
                                     d_model=96, num_layers=2), key)
    pol = PI.init_policy(PolicyConfig(env.obs_dim, env.act_dim, hidden=8),
                         key)
    trajs = [env.rollout(jax.random.fold_in(key, i), PI.sample_action, pol)
             for i in range(6)]
    obs = jnp.concatenate([t["obs"] for t in trajs])
    act = jnp.concatenate([t["act"] for t in trajs])
    nobs = jnp.concatenate([t["next_obs"] for t in trajs])
    wm.update_normalizer(jnp.concatenate([obs, nobs]))
    return env, wm, (obs, act, nobs)


def test_wm_learns_transitions(trained_wm):
    env, wm, (obs, act, nobs) = trained_wm
    key = jax.random.key(1)

    def mse():
        pred = wm.predict(obs[:64], act[:64], key)
        return float(jnp.mean((pred - nobs[:64]) ** 2))

    before = mse()
    for e in range(12):
        wm.train_epoch(obs, act, nobs, jax.random.fold_in(key, e))
    after = mse()
    assert after < before * 0.3, (before, after)


def test_wm_backed_policy_improvement(trained_wm):
    """The policy-improvement worker consumes the transformer world model
    through the same predict contract as the MLP ensemble."""
    env, wm, _ = trained_wm
    key = jax.random.key(2)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=8, imagine_horizon=6)
    algo = MEAlgo(acfg, PolicyConfig(env.obs_dim, env.act_dim, hidden=8),
                  jax.vmap(env.reward), env.reset_batch,
                  predict_fn=wm.predict_fn())
    state = algo.init(key)
    state2, info = algo.improve(state, wm.params, key)
    assert int(state2["steps"]) == 1
    assert jnp.isfinite(info["imagined_return"])
