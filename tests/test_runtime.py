"""Async framework behaviour tests (the paper's core claims, miniaturised)."""
import jax
import pytest

from repro.core import (AsyncTrainer, PartialAsyncDataPolicy,
                        PartialAsyncModelPolicy, RunConfig,
                        SequentialTrainer)
from repro.envs import make_env
from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig, make_algo


def build(env, algo="me-trpo", n_models=2):
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=32, n_models=n_models)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=16)
    acfg = AlgoConfig(algo=algo, imagine_batch=16, imagine_horizon=15,
                      n_models=n_models)
    return ens, make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)


def test_async_faster_wallclock():
    """Fig 2: async virtual run time ~= collection time << sequential."""
    env = make_env("pendulum")
    rc = RunConfig(total_trajs=6, seed=0)
    ens, algo = build(env)
    ta = AsyncTrainer(env, ens, algo, rc).run()
    ens, algo = build(env)
    ts = SequentialTrainer(env, ens, algo, rc).run()
    t_async, t_seq = ta[-1]["time"], ts[-1]["time"]
    collection_time = 6 * env.horizon * env.dt
    assert t_async <= collection_time * 1.05, \
        "async run time must collapse to sampling time"
    assert t_seq > t_async * 1.5, (t_seq, t_async)


def test_async_takes_many_policy_steps_per_traj():
    """The async schedule gives the policy worker many steps per rollout
    (removing the grad-steps-per-iteration hyperparameter, Sec. 4).
    After the warmup dataset (min_warmup_trajs=4), the worker takes
    ~traj_time/policy_step_time = 8 steps per collected trajectory."""
    env = make_env("pendulum")
    ens, algo = build(env)
    rc = RunConfig(total_trajs=8, seed=0)
    tr = AsyncTrainer(env, ens, algo, rc)
    tr.run()
    post_warmup = tr.collector.collected - rc.min_warmup_trajs
    assert tr.policy_worker.steps > 4 * post_warmup, \
        (tr.policy_worker.steps, post_warmup)


def test_partial_async_engines_run():
    env = make_env("pendulum")
    for eng in (PartialAsyncModelPolicy, PartialAsyncDataPolicy):
        ens, algo = build(env)
        trace = eng(env, ens, algo, RunConfig(total_trajs=6, seed=0)).run()
        assert trace and trace[-1]["trajs"] >= 6


def test_virtual_clock_speed_effect():
    """Fig 5b mechanism: slower collection => more policy steps/sample."""
    env = make_env("pendulum")
    steps_per_traj = {}
    for speed in (0.5, 2.0):
        ens, algo = build(env)
        tr = AsyncTrainer(env, ens, algo,
                          RunConfig(total_trajs=5, seed=0,
                                    collect_speed=speed))
        tr.run()
        steps_per_traj[speed] = tr.policy_worker.steps / tr.collector.collected
    assert steps_per_traj[0.5] > steps_per_traj[2.0]


def test_threads_mode_smoke():
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=3, seed=0),
                      mode="threads")
    trace = tr.run()
    assert tr.collector.collected >= 3
    assert trace[-1]["trajs"] >= 3


def test_threads_trace_times_relative_and_monotonic():
    """All trace rows must be seconds since run start (mid-run records
    used to be absolute time.monotonic() while the final row was
    relative)."""
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo,
                      RunConfig(total_trajs=4, seed=0,
                                eval_every_policy_steps=1),
                      mode="threads")
    trace = tr.run()
    times = [r["time"] for r in trace]
    assert all(0.0 <= t < 600.0 for t in times), times
    assert times == sorted(times), times


def test_run_config_not_shared_between_trainers():
    env = make_env("pendulum")
    ens, algo = build(env)
    a = AsyncTrainer(env, ens, algo)
    a.run_cfg.total_trajs = 999
    ens, algo = build(env)
    b = AsyncTrainer(env, ens, algo)
    assert b.run_cfg.total_trajs != 999


def test_stopping_criterion_total_trajs():
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=4, seed=1))
    tr.run()
    assert tr.collector.collected == 4
