"""Async framework behaviour tests (the paper's core claims, miniaturised)."""
import jax

from repro.core import (AsyncTrainer, PartialAsyncDataPolicy,
                        PartialAsyncModelPolicy, RunConfig,
                        SequentialTrainer)
from repro.envs import make_env
from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig, make_algo


def build(env, algo="me-trpo", n_models=2):
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=32, n_models=n_models)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=16)
    acfg = AlgoConfig(algo=algo, imagine_batch=16, imagine_horizon=15,
                      n_models=n_models)
    return ens, make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)


def test_async_faster_wallclock():
    """Fig 2: async virtual run time ~= collection time << sequential."""
    env = make_env("pendulum")
    rc = RunConfig(total_trajs=6, seed=0)
    ens, algo = build(env)
    ta = AsyncTrainer(env, ens, algo, rc).run()
    ens, algo = build(env)
    ts = SequentialTrainer(env, ens, algo, rc).run()
    t_async, t_seq = ta[-1]["time"], ts[-1]["time"]
    collection_time = 6 * env.horizon * env.dt
    assert t_async <= collection_time * 1.05, \
        "async run time must collapse to sampling time"
    assert t_seq > t_async * 1.5, (t_seq, t_async)


def test_async_takes_many_policy_steps_per_traj():
    """The async schedule gives the policy worker many steps per rollout
    (removing the grad-steps-per-iteration hyperparameter, Sec. 4).
    After the warmup dataset (min_warmup_trajs=4), the worker takes
    ~traj_time/policy_step_time = 8 steps per collected trajectory."""
    env = make_env("pendulum")
    ens, algo = build(env)
    rc = RunConfig(total_trajs=8, seed=0)
    tr = AsyncTrainer(env, ens, algo, rc)
    tr.run()
    post_warmup = tr.collector.collected - rc.min_warmup_trajs
    assert tr.policy_worker.steps > 4 * post_warmup, \
        (tr.policy_worker.steps, post_warmup)


def test_partial_async_engines_run():
    env = make_env("pendulum")
    for eng in (PartialAsyncModelPolicy, PartialAsyncDataPolicy):
        ens, algo = build(env)
        trace = eng(env, ens, algo, RunConfig(total_trajs=6, seed=0)).run()
        assert trace and trace[-1]["trajs"] >= 6


def test_virtual_clock_speed_effect():
    """Fig 5b mechanism: slower collection => more policy steps/sample."""
    env = make_env("pendulum")
    steps_per_traj = {}
    for speed in (0.5, 2.0):
        ens, algo = build(env)
        tr = AsyncTrainer(env, ens, algo,
                          RunConfig(total_trajs=5, seed=0,
                                    collect_speed=speed))
        tr.run()
        steps_per_traj[speed] = tr.policy_worker.steps / tr.collector.collected
    assert steps_per_traj[0.5] > steps_per_traj[2.0]


def test_threads_mode_smoke():
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=3, seed=0),
                      mode="threads")
    trace = tr.run()
    assert tr.collector.collected >= 3
    assert trace[-1]["trajs"] >= 3


def test_threads_trace_times_relative_and_monotonic():
    """All trace rows must be seconds since run start (mid-run records
    used to be absolute time.monotonic() while the final row was
    relative)."""
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo,
                      RunConfig(total_trajs=4, seed=0,
                                eval_every_policy_steps=1),
                      mode="threads")
    trace = tr.run()
    times = [r["time"] for r in trace]
    assert all(0.0 <= t < 600.0 for t in times), times
    assert times == sorted(times), times


def test_run_config_not_shared_between_trainers():
    env = make_env("pendulum")
    ens, algo = build(env)
    a = AsyncTrainer(env, ens, algo)
    a.run_cfg.total_trajs = 999
    ens, algo = build(env)
    b = AsyncTrainer(env, ens, algo)
    assert b.run_cfg.total_trajs != 999


def test_stopping_criterion_total_trajs():
    env = make_env("pendulum")
    ens, algo = build(env)
    tr = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=4, seed=1))
    tr.run()
    assert tr.collector.collected == 4


def test_event_engine_bit_identical_and_speed_stable():
    """Regression harness for the paper's Fig. 5b machinery: the
    discrete-event engine is DETERMINISTIC — two runs with the same
    ``RunConfig.seed`` produce bit-identical traces — and its
    virtual-time cursor ordering stays stable (monotone trace, final
    virtual time scaling with 1/collect_speed) across collection
    speeds."""
    env = make_env("pendulum")
    final_times = {}
    for speed in (0.5, 1.0, 2.0):
        traces = []
        for _ in range(2):
            ens, algo = build(env)
            tr = AsyncTrainer(env, ens, algo,
                              RunConfig(total_trajs=4, seed=3,
                                        collect_speed=speed))
            traces.append(tr.run())
        assert traces[0] == traces[1], \
            f"event engine non-deterministic at collect_speed={speed}"
        times = [r["time"] for r in traces[0]]
        assert times == sorted(times), times
        final_times[speed] = times[-1]
    # the virtual clock is exact: collection dominates run time, so the
    # final cursor scales inversely with collection speed
    assert final_times[0.5] > final_times[1.0] > final_times[2.0], \
        final_times


def test_eval_cache_bounded_and_clearable():
    """_EVAL_CACHE shares one compiled eval across value-equal envs, is
    LRU-bounded (env variant sweeps can't grow it without bound), and is
    explicitly clearable for benchmarks."""
    from repro.core import runtime
    from repro.envs.classic import Pendulum

    runtime.clear_eval_cache()
    env = Pendulum(max_torque=1.875)        # value distinct from other tests
    fn1 = runtime._eval_fn(env, 2)
    assert runtime._eval_fn(Pendulum(max_torque=1.875), 2) is fn1, \
        "value-equal envs must share one compiled eval"
    assert runtime._eval_fn(env, 3) is not fn1
    assert len(runtime._EVAL_CACHE) == 2
    # sweep many env variants: the LRU bound holds and the most recently
    # used entry survives
    runtime._eval_fn(env, 2)                # touch -> fn1 becomes newest
    for i in range(runtime._EVAL_CACHE_MAX + 5):
        runtime._eval_fn(Pendulum(max_torque=3.0 + i), 2)
    assert len(runtime._EVAL_CACHE) == runtime._EVAL_CACHE_MAX
    assert (env, 3) not in runtime._EVAL_CACHE, "oldest entry must evict"
    runtime.clear_eval_cache()
    assert len(runtime._EVAL_CACHE) == 0


def test_eval_cache_eviction_keeps_live_recorders_working():
    """An LRU-evicted entry must strand nothing: a _Recorder built before
    the eviction keeps its own fn and still evaluates."""
    import jax
    import numpy as np

    from repro.core import runtime
    from repro.envs.classic import Pendulum

    runtime.clear_eval_cache()
    env = Pendulum(max_torque=1.9375)
    rec = runtime._Recorder(env, 2)
    for i in range(runtime._EVAL_CACHE_MAX + 1):    # evict rec's entry
        runtime._eval_fn(Pendulum(max_torque=5.0 + i), 2)
    assert (env, 2) not in runtime._EVAL_CACHE
    pol = runtime.PI.init_policy(
        runtime.PI.PolicyConfig(env.obs_dim, env.act_dim, hidden=4),
        jax.random.key(0))
    ret = rec.record(0.0, 1, pol, jax.random.key(1))    # first trace here
    assert np.isfinite(ret)
    runtime.clear_eval_cache()
