"""Fallback for the ``hypothesis`` property-testing library.

The real hypothesis is used when installed (see requirements-dev.txt).
When it is missing, this module provides a tiny deterministic stand-in so
the property tests still COLLECT and exercise a fixed number of seeded
random cases instead of hard-failing at import. Only the strategy
surface this repo uses is implemented: integers, floats, lists,
sampled_from.

Usage in test modules:

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                           # fallback
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(choices):
            seq = list(choices)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _Strategies()

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            # NOTE: deliberately no functools.wraps — copying fn's
            # signature would make pytest treat the property arguments
            # as fixtures
            def wrapper(*args, **kwargs):
                # @settings may sit outside (attribute lands on wrapper)
                # or inside @given (attribute lands on fn) — honor both
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
                rng = random.Random(f"repro:{fn.__name__}")
                for _ in range(n):
                    drawn_args = tuple(s.draw(rng) for s in arg_strategies)
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn_args, **kwargs, **drawn_kw)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return decorate

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn
        return decorate
