"""Server semantics + concurrency (hypothesis property tests)."""
import threading

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.servers import DataServer, LocalBuffer, ParameterServer


def test_parameter_server_versioning():
    ps = ParameterServer()
    v0, ver = ps.pull()
    assert v0 is None and ver == 0
    ps.push({"w": jnp.ones(3)})
    val, ver = ps.pull()
    assert ver == 1 and np.allclose(val["w"], 1)
    ps.push({"w": jnp.zeros(3)})
    val, ver = ps.pull()
    assert ver == 2 and np.allclose(val["w"], 0)


def test_data_server_drain_moves_all():
    ds = DataServer()
    for i in range(5):
        ds.push({"x": np.full(2, i)})
    items = ds.drain()
    assert len(items) == 5 and len(ds) == 0
    assert ds.total_pushed == 5
    assert ds.drain() == []


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 100), min_size=1, max_size=60),
       st.integers(2, 20))
def test_local_buffer_fifo_bound(sizes, max_trajs):
    """Property: train buffer never exceeds max_trajs; total_seen counts
    everything; val split stays a bounded fraction."""
    buf = LocalBuffer(max_trajs=max_trajs)
    for i, s in enumerate(sizes):
        buf.extend([{"obs": np.full((2, 1), i)}])
    assert buf.n_train <= max_trajs
    assert buf.total_seen == len(sizes)
    data = buf.train_arrays()
    assert data is not None and data["obs"].shape[0] == buf.n_train * 2


def test_local_buffer_fifo_order():
    buf = LocalBuffer(max_trajs=3, holdout_frac=0.0)
    for i in range(6):
        buf.extend([{"obs": np.full((1,), i)}])
    data = buf.train_arrays()
    # oldest evicted: 3, 4, 5 remain
    assert sorted(data["obs"].tolist()) == [3.0, 4.0, 5.0]


def test_concurrent_push_pull():
    """Hogwild-spirit: concurrent pushes and pulls never corrupt state."""
    ps = ParameterServer({"w": jnp.zeros(4)})
    stop = threading.Event()
    errors = []

    def pusher(v):
        for i in range(100):
            ps.push({"w": jnp.full(4, float(v))})

    def puller():
        while not stop.is_set():
            val, _ = ps.pull()
            arr = np.asarray(val["w"])
            if not np.all(arr == arr[0]):
                errors.append(arr)

    threads = [threading.Thread(target=pusher, args=(i,)) for i in range(3)]
    pt = threading.Thread(target=puller)
    pt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    pt.join()
    assert not errors, "torn read observed"
    assert ps.version == 301
