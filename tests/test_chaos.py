"""Chaos engine + soak harness (PR 7) — ISSUE 7 acceptance tests.

Unit-level: deterministic seeded fault plans with guaranteed role
coverage and budget caps; the supervisor seam (chain fan-out, delayed
respawns); the invariant monitor catching planted violations against
fake trainers; the resource auditor catching planted fd / registry
leaks; server context managers + audit registries.

End-to-end (``slow``): the micro soak profile — a real
``AsyncTrainer(mode="procs")`` run under seeded SIGKILLs and stalls —
completes with zero invariant violations and zero leaked resources.
"""
import json
import os
import signal
import subprocess
import time

import pytest

from repro.chaos import (KILL, STALL, ChaosSupervisor, FaultEvent,
                         FaultPlan, InvariantMonitor, ResourceAuditor)
from repro.chaos.audit import warmup_ipc
from repro.chaos.faults import role_family
from repro.core import (RunConfig, Supervisor, SupervisorChain,
                        live_data_servers, live_shm_segments)
from repro.core.workers import heartbeat_slot


# ------------------------------------------------------------ fault plans
def test_fault_plan_deterministic_and_covering():
    kw = dict(n_collectors=3, n_faults=14, max_kills_per_role=3)
    p1 = FaultPlan.generate(7, **kw)
    p2 = FaultPlan.generate(7, **kw)
    assert p1 == p2, "same seed must give an identical plan"
    assert FaultPlan.generate(8, **kw) != p1, "seeds must differ"
    assert len(p1.events) == 14
    assert p1.families() == ("collector", "model", "policy")
    kinds = {e.kind for e in p1.events}
    assert kinds == {KILL, STALL}
    kills = {}
    for e in p1.events:
        assert 0.05 <= e.at <= 0.85
        if e.kind == KILL:
            kills[e.role] = kills.get(e.role, 0) + 1
    assert kills and max(kills.values()) <= 3
    ats = [e.at for e in p1.events]
    assert ats == sorted(ats)


def test_fault_plan_covers_all_families_for_many_seeds():
    for seed in range(20):
        p = FaultPlan.generate(seed, n_collectors=2, n_faults=5,
                               max_kills_per_role=2)
        assert p.families() == ("collector", "model", "policy"), seed


# ------------------------------------------------------- supervisor seam
class _Recording(Supervisor):
    def __init__(self, delay=0.0):
        self.calls = []
        self.delay = delay

    def attach(self, trainer):
        super().attach(trainer)
        self.calls.append("attach")

    def detach(self):
        super().detach()
        self.calls.append("detach")

    def on_tick(self):
        self.calls.append("tick")

    def respawn_delay(self, role):
        return self.delay


def test_supervisor_chain_fans_out_and_maxes_delay():
    a, b = _Recording(delay=0.2), _Recording(delay=0.7)
    chain = SupervisorChain(a, b)
    chain.attach(object())
    chain.on_tick()
    assert chain.respawn_delay("model") == 0.7, \
        "chain must take the MAX member delay"
    chain.detach()
    for m in (a, b):
        assert m.calls == ["attach", "tick", "detach"]
        assert m.trainer is None


def test_supervisor_rejected_outside_procs_mode():
    from repro.core import AsyncTrainer
    with pytest.raises(ValueError, match="procs"):
        AsyncTrainer(None, None, None, mode="event",
                     supervisor=Supervisor())


# ------------------------------------------------- monitor (fake trainer)
class _FakeSrv:
    def __init__(self, version=0):
        self.version = version


class _FakeData:
    def __init__(self, pushed=0):
        self.total_pushed = pushed


class _FakeChannels:
    def __init__(self):
        self.beats = {}

    def read_heartbeat(self, slot):
        return self.beats.get(slot, (0.0, 0.0))


class _FakeTrainer:
    def __init__(self, rc):
        self.run_cfg = rc
        self._proc_servers = {"model": _FakeSrv(), "policy": _FakeSrv(),
                              "data": _FakeData()}
        self.proc_info = {"restarts": {"model": 0, "policy": 0,
                                       "collector:0": 0}}
        self._proc_channels = _FakeChannels()


def _monitored(rc=None):
    tr = _FakeTrainer(rc or RunConfig(total_trajs=10, max_restarts=2))
    mon = InvariantMonitor(check_every_s=0.0)
    mon.attach(tr)
    return tr, mon


def test_monitor_clean_run_has_no_violations():
    tr, mon = _monitored()
    tr._proc_servers["model"].version = 3
    tr._proc_servers["data"].total_pushed = 10
    mon.on_tick()
    tr._proc_servers["data"].total_pushed = 10
    mon.on_complete()
    assert mon.violations == []


def test_monitor_flags_version_regression():
    tr, mon = _monitored()
    tr._proc_servers["model"].version = 5
    mon.on_tick()
    tr._proc_servers["model"].version = 2     # a restart reset the word
    mon.on_tick()
    assert any("BACKWARDS" in v for v in mon.violations)


def test_monitor_flags_criterion_overshoot_and_miss():
    tr, mon = _monitored()
    tr._proc_servers["data"].total_pushed = 11      # > total_trajs=10
    mon.on_tick()
    assert any("OVERSHOT" in v for v in mon.violations)
    tr2, mon2 = _monitored()
    tr2._proc_servers["data"].total_pushed = 9      # landed short
    mon2.on_complete()
    assert any("criterion missed" in v for v in mon2.violations)


def test_monitor_flags_retrace_and_budget():
    tr, mon = _monitored()
    slot = heartbeat_slot("model", tr.run_cfg.n_collectors)
    tr._proc_channels.beats[slot] = (1.0, 3.0)      # 3 compiles, cap 1
    tr.proc_info["restarts"]["collector:0"] = 99
    mon.on_tick()
    assert any("RETRACED" in v for v in mon.violations)
    assert any("restart budget" in v for v in mon.violations)
    # unknown compile counts (-1) are not violations
    tr2, mon2 = _monitored()
    slot2 = heartbeat_slot("policy", tr2.run_cfg.n_collectors)
    tr2._proc_channels.beats[slot2] = (1.0, -1.0)
    mon2.on_tick()
    assert mon2.violations == []


# --------------------------------------------- chaos injection (no jax)
class _PopenProc:
    """Adapter giving a subprocess the mp.Process surface chaos uses."""

    def __init__(self, argv=("sleep", "60")):
        self._p = subprocess.Popen(argv)
        self.pid = self._p.pid

    @property
    def exitcode(self):
        return self._p.poll()

    def kill(self):
        try:
            self._p.kill()
        except OSError:
            pass
        self._p.wait()


def _chaos_trainer(procs, total=10, pushed=5, max_restarts=2):
    tr = _FakeTrainer(RunConfig(total_trajs=total,
                                max_restarts=max_restarts))
    tr._procs = procs
    tr._proc_servers["data"].total_pushed = pushed
    return tr


def test_chaos_kill_injection_and_respawn_delay():
    p = _PopenProc()
    try:
        plan = FaultPlan(seed=0, events=(
            FaultEvent(at=0.1, kind=KILL, role="model", arg=0.25),))
        sup = ChaosSupervisor(plan)
        sup.attach(_chaos_trainer({"model": p}))
        sup.on_tick()       # progress 0.5 >= 0.1: fires
        assert len(sup.injected) == 1
        deadline = time.monotonic() + 10
        while p.exitcode is None and time.monotonic() < deadline:
            time.sleep(0.02)
        assert p.exitcode == -signal.SIGKILL
        assert sup.respawn_delay("model") == 0.25
        assert sup.respawn_delay("model") == 0.0    # one-shot
    finally:
        p.kill()


def test_chaos_stall_then_resume():
    p = _PopenProc()
    try:
        plan = FaultPlan(seed=0, events=(
            FaultEvent(at=0.1, kind=STALL, role="model", arg=0.2),))
        sup = ChaosSupervisor(plan)
        sup.attach(_chaos_trainer({"model": p}))
        sup.on_tick()
        assert sup.injected and sup.injected[0]["kind"] == STALL

        def state():
            with open(f"/proc/{p.pid}/stat") as f:
                return f.read().rsplit(")", 1)[1].split()[0]

        deadline = time.monotonic() + 5
        while state() != "T" and time.monotonic() < deadline:
            time.sleep(0.02)    # signal delivery is asynchronous
        assert state() == "T", "child not SIGSTOPped"
        time.sleep(0.25)
        sup.on_tick()       # stall expired: SIGCONT
        deadline = time.monotonic() + 5
        while state() == "T" and time.monotonic() < deadline:
            time.sleep(0.02)
        assert state() != "T", "child never resumed"
        assert p.exitcode is None
    finally:
        p.kill()


def test_chaos_skips_kill_without_budget_headroom_and_defers_when_down():
    kill_model = FaultEvent(at=0.1, kind=KILL, role="model", arg=0.0)
    # no headroom: restarts already at max_restarts -> skipped, loudly
    dead = _FakeTrainer(RunConfig(total_trajs=10, max_restarts=2))
    dead._procs = {"model": _PopenProc()}
    try:
        dead._proc_servers["data"].total_pushed = 5
        dead.proc_info["restarts"]["model"] = 2
        sup = ChaosSupervisor(FaultPlan(seed=0, events=(kill_model,)))
        sup.attach(dead)
        sup.on_tick()
        assert not sup.injected
        assert sup.skipped and "headroom" in sup.skipped[0]["reason"]
    finally:
        dead._procs["model"].kill()
    # role currently down (exitcode set) -> deferred, not dropped
    class _DeadProc:
        pid = 1
        exitcode = -9
    tr = _chaos_trainer({"model": _DeadProc()})
    sup = ChaosSupervisor(FaultPlan(seed=0, events=(kill_model,)))
    sup.attach(tr)
    sup.on_tick()
    assert not sup.injected and not sup.skipped
    assert len(sup._queue) == 1
    sup.on_complete()       # run ends first: flushed as skipped
    assert sup.skipped and "completed" in sup.skipped[0]["reason"]


# ------------------------------------------------------- resource audit
def test_auditor_catches_fd_leak_then_clean():
    warmup_ipc()
    auditor = ResourceAuditor()
    auditor.baseline()
    r, w = os.pipe()
    report = auditor.audit(settle_s=0.3)
    assert not report["ok"]
    assert any("pipe:" in f for f in report["leaked_fds"])
    os.close(r)
    os.close(w)
    assert auditor.audit(settle_s=2.0)["ok"]


def test_auditor_catches_unclosed_server_then_reclaim():
    import numpy as np

    from repro.core import ShmParameterServer
    warmup_ipc()
    auditor = ResourceAuditor()
    auditor.baseline()
    srv = ShmParameterServer({"w": np.zeros((4,), np.float32)})
    report = auditor.audit(settle_s=0.3)
    assert not report["ok"]
    assert report["registries"]["shm_segments"], \
        "unclosed ShmParameterServer missing from the audit registry"
    srv.close()
    report = auditor.audit(settle_s=2.0)
    assert report["ok"], report


# ------------------------------------- context managers + registries
def test_shm_server_context_manager_and_registry():
    import numpy as np

    from repro.core import ShmParameterServer
    base = live_shm_segments()
    with ShmParameterServer({"w": np.zeros((2,), np.float32)}) as srv:
        assert len(live_shm_segments()) == len(base) + 1
        srv.push({"w": np.ones((2,), np.float32)})
        assert srv.version == 1
    assert live_shm_segments() == base
    srv.close()     # idempotent


def test_proc_data_server_context_manager_and_registry():
    import multiprocessing as mp

    from repro.core import ProcDataServer
    ctx = mp.get_context("spawn")
    base = live_data_servers()
    with ProcDataServer(ctx, n_collectors=2, target=4) as ds:
        assert live_data_servers() == base + 1
        assert ds.try_claim(0, k=4) == 4
    assert live_data_servers() == base
    ds.close()      # idempotent
    assert ds.total_pushed == 0     # counters stay readable after close


# ------------------------------------------------------ end-to-end soak
@pytest.mark.slow
@pytest.mark.timeout(540)
def test_soak_micro_end_to_end(tmp_path):
    """The micro chaos profile: a real procs run under seeded kills and
    stalls completes with zero violations and zero leaks, and the
    machine-readable report says so."""
    from repro.chaos.soak import run_soak
    out = tmp_path / "SOAK_report.json"
    code = run_soak("micro", 0, out=str(out))
    rep = json.loads(out.read_text())
    assert code == 0 and rep["ok"], rep["problems"]
    (run,) = rep["runs"]
    assert run["error"] is None
    assert run["monitor"]["violations"] == []
    assert run["audit"]["ok"], run["audit"]
    injected = run["faults"]["injected"]
    assert len(injected) >= 3
    assert {role_family(f["role"]) for f in injected} == \
        {"collector", "model", "policy"}
    assert run["trajs"] == rep["config"]["total_trajs"], \
        "chaos run missed the exact criterion"
    assert run["model_version"] >= 1 and run["policy_version"] >= 1
