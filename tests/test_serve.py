"""Serving-tier tests (PR 8): continuous batching, paged KV pool,
live hot-swap, and the api.grow_cache helper.

The contracts asserted here are the ROADMAP "Serving-tier invariants":

* compile-once under churn — with requests admitted/retired
  continuously, the decode program traces exactly once and prefill/admit
  trace at most once per prompt bucket (counted via utils.jit_stats);
* FIFO admission with head-of-line blocking — admission order is
  submission order, and a request that does not fit (slot- or
  page-starved) blocks everything behind it;
* page conservation — free + held pages == n_pages at every step;
* request isolation — a request's tokens are identical whether it is
  served alone or with co-tenant slots churning next to it;
* hot-swap correctness — a mid-decode push is picked up on the next
  tick (verified against a manual mixed-version replay through the SAME
  compiled functions), post-swap requests match a fresh server started
  at the new version, and the unchanged-version pull performs zero
  transfers (jax.transfer_guard('disallow')).
"""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.servers import BackpressureError, ParameterServer
from repro.launch.mesh import make_smoke_mesh
from repro.models import api
from repro.models import lm as LM
from repro.models.config import InputShape
from repro.serve import RequestQueue, WorldModelServer
from repro.serve.kv_pool import _admit_update


@pytest.fixture(scope="module")
def cfg():
    return get_config("glm4-9b", reduced=True)


@pytest.fixture(scope="module")
def mesh():
    return make_smoke_mesh()


@pytest.fixture(scope="module")
def params_v1(cfg, mesh):
    return LM.init_params(cfg, api.shard_ctx(mesh), jax.random.key(1))


@pytest.fixture(scope="module")
def params_v2(cfg, mesh):
    return LM.init_params(cfg, api.shard_ctx(mesh), jax.random.key(2))


@pytest.fixture(scope="module")
def server(cfg, params_v1):
    """Shared small server: 2 slots, buckets (8, 16), 8-token pages."""
    return WorldModelServer(cfg, params=params_v1, n_slots=2, max_seq=32,
                            page_len=8, prompt_buckets=(8, 16))


def _prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, n).astype(np.int32)


# -- grow_cache (satellite: replaces the example's hand-rolled pad) --------


def test_grow_cache_matches_manual_pad():
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(2, 3, 4, 2, 5)).astype(np.float32))
    cache = {"index": jnp.asarray(4, jnp.int32), "k": k,
             "v": k * 2, "pos": jnp.asarray([0, 1, 2, 3], jnp.int32),
             "k_scale": jnp.ones((2, 3, 4, 2, 1), jnp.float32),
             "v_scale": jnp.ones((2, 3, 4, 2, 1), jnp.float32)}
    out = api.grow_cache(cache, 7)
    pad5 = ((0, 0),) * 2 + ((0, 3),) + ((0, 0),) * 2
    np.testing.assert_array_equal(out["k"], jnp.pad(k, pad5))
    np.testing.assert_array_equal(out["v"], jnp.pad(k * 2, pad5))
    assert out["k_scale"].shape == (2, 3, 7, 2, 1)
    # THE bug this helper prevents: pos pads with -1 (empty), never 0
    np.testing.assert_array_equal(
        out["pos"], jnp.asarray([0, 1, 2, 3, -1, -1, -1], jnp.int32))
    assert out["index"] == 4

    # per-slot (B, S) pos layout pads the last axis the same way
    slot = {"index": jnp.asarray([2], jnp.int32), "k": k[:, :1],
            "v": k[:, :1], "pos": jnp.asarray([[0, 1, -1, -1]], jnp.int32)}
    out2 = api.grow_cache(slot, 6)
    np.testing.assert_array_equal(
        out2["pos"], jnp.asarray([[0, 1, -1, -1, -1, -1]], jnp.int32))

    same = api.grow_cache(cache, 4)  # no-op at current capacity
    assert same["k"] is cache["k"]
    with pytest.raises(ValueError, match="shrink"):
        api.grow_cache(cache, 3)
    with pytest.raises(ValueError, match="attention"):
        api.grow_cache({"ssm": k, "index": 0}, 8)


# -- model layer: per-slot programs match the lock-step reference ----------


def test_slot_decode_matches_lockstep(cfg, mesh, params_v1):
    B, PLEN, GEN = 2, 8, 4
    pre_s = api.build_serve_prefill(cfg, mesh, B, PLEN)
    dec_s = api.build_serve_decode(cfg, mesh, B, PLEN + GEN + 1)
    pre_l = api.build(cfg, mesh, InputShape("p", PLEN, B, "prefill"))
    dec_l = api.build(cfg, mesh,
                      InputShape("d", PLEN + GEN + 1, B, "decode"))
    prompts = jnp.asarray(
        np.stack([_prompt(cfg, PLEN, s) for s in (3, 4)]))

    lg_s, c_s = pre_s.fn(params_v1, {"tokens": prompts},
                         jnp.full((B,), PLEN, jnp.int32))
    lg_l, c_l = pre_l.fn(params_v1, {"tokens": prompts})
    # full-bucket prompts: per-row last-real-token == last-token logits
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_l),
                               atol=1e-2, rtol=1e-3)
    c_s = api.grow_cache(c_s, dec_s.abstract_args[1]["k"].shape[2])
    c_l = api.grow_cache(c_l, dec_l.abstract_args[1]["k"].shape[2])

    tok = jnp.argmax(lg_s[:, :cfg.vocab_size], -1).astype(jnp.int32)
    tok_l = jnp.argmax(lg_l[:, :cfg.vocab_size], -1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok_l))
    active = jnp.ones((B,), jnp.bool_)
    for _ in range(GEN):
        lg_s, c_s = dec_s.fn(params_v1, c_s, tok[:, None], active)
        lg_l, c_l = dec_l.fn(params_v1, c_l, tok[:, None])
        tok = jnp.argmax(lg_s[:, :cfg.vocab_size], -1).astype(jnp.int32)
        tok_l = jnp.argmax(lg_l[:, :cfg.vocab_size], -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok_l))
        np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_l),
                                   atol=1e-2, rtol=1e-3)


def test_serve_rejects_stateless_cache_families(mesh):
    ssm = get_config("mamba2-2.7b", reduced=True)
    with pytest.raises(ValueError, match="attention KV cache"):
        api.build_serve_decode(ssm, mesh, 2, 32)


# -- continuous batching: isolation, FIFO, no-retrace under churn ----------


def test_request_isolation_under_cotenancy(cfg, server):
    """Same request, same server: tokens identical served alone vs with
    co-tenant slots churning next to it (row independence of the single
    compiled decode program)."""
    prompt = _prompt(cfg, 6, 10)
    rid_alone = server.submit(prompt, max_new=5)
    server.run()
    alone = server.result(rid_alone)
    assert alone.shape == (5,)

    rid_again = server.submit(prompt, max_new=5)
    server.step()  # admits rid_again into slot 0, decodes one token
    rid_b = server.submit(_prompt(cfg, 13, 11), max_new=4)
    rid_c = server.submit(_prompt(cfg, 3, 12), max_new=6)
    server.run()
    np.testing.assert_array_equal(server.result(rid_again), alone)
    assert server.result(rid_b).shape == (4,)
    assert server.result(rid_c).shape == (6,)


def test_churn_fifo_no_retrace_page_conservation(cfg, server):
    """A stream of mixed-size requests churning through 2 slots: FIFO
    admission, compile counts pinned at their bucket caps, and page
    accounting conserved at every step."""
    start_order = len(server.sched.admit_order)
    rids = []
    specs = [(3, 4), (8, 3), (11, 5), (5, 2), (16, 4), (2, 6), (9, 3),
             (7, 5)]
    for i, (plen, new) in enumerate(specs):
        rids.append(server.submit(_prompt(cfg, plen, 20 + i), max_new=new))
        if i % 3 == 2:  # interleave serving with submission
            server.step()
        free, held = server.sched.pool.accounting()
        assert free + held == server.sched.pool.n_pages
    while server.pending:
        server.step()
        free, held = server.sched.pool.accounting()
        assert free + held == server.sched.pool.n_pages

    # FIFO: admission order == submission order (head-of-line blocking)
    assert server.sched.admit_order[start_order:] == rids
    for rid, (_, new) in zip(rids, specs):
        assert server.result(rid).shape == (new,)
    # compile-once under churn: everything pinned at its fixed-shape cap
    cc = server.sched.compile_counts()
    assert cc["decode"] == 1, cc
    assert cc["prefill"] <= len(server.sched.buckets), cc
    assert cc["admit"] <= len(server.sched.buckets), cc
    free, held = server.sched.pool.accounting()
    assert (free, held) == (server.sched.pool.n_pages, 0)


def test_backpressure_and_submit_validation(cfg, server):
    q = RequestQueue(maxsize=2, submit_timeout=0.0)
    q.submit("a")
    q.submit("b")
    with pytest.raises(BackpressureError, match="decode loop"):
        q.submit("c")
    assert q.pop() == "a" and q.pop() == "b"  # FIFO

    # server-side validation refuses requests that can NEVER be served
    with pytest.raises(ValueError, match="largest.*bucket"):
        server.submit(_prompt(cfg, 17, 0), max_new=2)
    with pytest.raises(ValueError, match="capacity"):
        server.submit(_prompt(cfg, 16, 0), max_new=100)
    with pytest.raises(ValueError, match="empty"):
        server.submit([], max_new=2)

    # and through the server: a full queue sheds load with the same error
    old = server.queue.maxsize
    try:
        server.queue.maxsize = 1
        server.submit(_prompt(cfg, 4, 1), max_new=1)
        with pytest.raises(BackpressureError):
            server.submit(_prompt(cfg, 4, 2), max_new=1)
    finally:
        server.queue.maxsize = old
        server.run()


def test_page_exhaustion_blocks_admission(cfg, params_v1):
    """Paging is real admission currency: with 2 free slots but only
    enough pages for one request, the second waits for retirement."""
    srv = WorldModelServer(cfg, params=params_v1, n_slots=2, max_seq=32,
                           page_len=16, n_pages=2, prompt_buckets=(16,))
    r1 = srv.submit(_prompt(cfg, 14, 30), max_new=6)   # 20 tokens: 2 pages
    r2 = srv.submit(_prompt(cfg, 12, 31), max_new=6)   # 18 tokens: 2 pages
    srv.step()
    assert srv.sched.slot_req[0] is not None           # r1 decoding
    assert len(srv.queue) == 1                         # r2 page-starved
    assert srv.sched.pool.accounting() == (0, 2)
    srv.run()
    assert srv.result(r1).shape == (6,)
    assert srv.result(r2).shape == (6,)
    assert srv.sched.pool.accounting() == (2, 0)
    assert srv.sched.admit_order == [r1, r2]


# -- hot-swap ---------------------------------------------------------------


def test_hotswap_mid_decode_and_zero_transfer_pulls(cfg, params_v1,
                                                    params_v2):
    ps = ParameterServer()
    ps.push(params_v1)
    srv = WorldModelServer(cfg, param_server=ps, n_slots=1, max_seq=32,
                           prompt_buckets=(8,))
    prompt = _prompt(cfg, 5, 40)
    rid = srv.submit(prompt, max_new=6)
    srv.step()   # admit (prefill token) + decode tick 1     -> v1
    srv.step()   # decode tick 2                             -> v1
    with jax.transfer_guard("disallow"):
        assert srv.maybe_swap() is False  # unchanged: zero transfers
    ps.push(params_v2)
    srv.run()    # decode ticks 3..5 pick up v2 on the next step
    got = srv.result(rid)
    assert srv.swaps == 1

    # manual mixed-version replay through the SAME compiled functions
    sched = srv.sched
    batch = np.zeros((1, 8), np.int32)
    batch[0, :5] = prompt
    lg, pre_cache = sched.pre[8].fn(params_v1, {"tokens": jnp.asarray(batch)},
                                    jnp.asarray([5], jnp.int32))
    cache = _admit_update(  # eager call of the admission scatter
        LM.init_cache_slots(cfg, sched.dec.ctx, 1, 32), pre_cache,
        jnp.asarray(0, jnp.int32))
    toks = [int(np.asarray(jnp.argmax(lg[0, :cfg.vocab_size])))]
    active = jnp.ones((1,), jnp.bool_)
    for step in range(5):
        params = params_v1 if step < 2 else params_v2
        lg, cache = sched.dec.fn(params, cache,
                                 jnp.asarray([[toks[-1]]], jnp.int32),
                                 active)
        toks.append(int(np.asarray(jnp.argmax(lg[0, :cfg.vocab_size]))))
    np.testing.assert_array_equal(got, np.asarray(toks, np.int32))

    # post-swap requests are bit-identical to a fresh server at v2
    prompt_b = _prompt(cfg, 7, 41)
    rid_b = srv.submit(prompt_b, max_new=5)
    srv.run()
    fresh = WorldModelServer(cfg, params=params_v2, n_slots=1, max_seq=32,
                             prompt_buckets=(8,))
    rid_f = fresh.submit(prompt_b, max_new=5)
    fresh.run()
    np.testing.assert_array_equal(srv.result(rid_b), fresh.result(rid_f))


# -- the example path -------------------------------------------------------


def test_example_serve_smoke():
    path = (pathlib.Path(__file__).resolve().parent.parent / "examples"
            / "serve_world_model.py")
    spec = importlib.util.spec_from_file_location("serve_example", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    mod.PROMPT, mod.GEN, mod.BATCH = 8, 3, 2  # shrink for CI
    mod.main()
