"""MBRL substrate tests: envs, dynamics ensemble, TRPO/PPO, algorithms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import make_env
from repro.mbrl import dynamics as DYN
from repro.mbrl import policy as PI
from repro.mbrl import ppo as PPO
from repro.mbrl import trpo as TRPO
from repro.mbrl.algos import AlgoConfig, make_algo
from repro.mbrl.policy import PolicyConfig

ENVS = ["pendulum", "cartpole_swingup", "spring_hopper", "reacher2",
        "pr2_reach"]


@pytest.mark.parametrize("name", ENVS)
def test_env_rollout_finite(name):
    env = make_env(name)
    pol = PI.init_policy(PolicyConfig(env.obs_dim, env.act_dim, hidden=8),
                         jax.random.key(0))
    tr = jax.jit(lambda k: env.rollout(k, PI.sample_action, pol))(
        jax.random.key(1))
    assert tr["obs"].shape == (env.horizon, env.obs_dim)
    for k, v in tr.items():
        assert jnp.isfinite(v).all(), k


@pytest.mark.parametrize("name", ENVS)
def test_env_reward_consistency(name):
    """step()'s returned reward equals reward(s, a, s') — required for
    imagination to be faithful to the env."""
    env = make_env(name)
    key = jax.random.key(2)
    s = env.reset(key)
    for i in range(5):
        a = jax.random.uniform(jax.random.fold_in(key, i), (env.act_dim,),
                               minval=-1, maxval=1)
        s2, r = env.step(s, a)
        r2 = env.reward(s, a, s2)
        np.testing.assert_allclose(float(r), float(r2), rtol=1e-5, atol=1e-5)
        s = s2


def test_ensemble_learns_dynamics():
    """The ensemble must fit a simple known system well."""
    env = make_env("pendulum")
    cfg = DYN.EnsembleConfig(env.obs_dim, env.act_dim, hidden=64,
                             n_models=2, lr=3e-3)
    key = jax.random.key(0)
    params = DYN.init_ensemble(cfg, key)
    pol = PI.init_policy(PolicyConfig(env.obs_dim, env.act_dim, hidden=8),
                         key)
    trajs = [env.rollout(jax.random.fold_in(key, i), PI.sample_action, pol)
             for i in range(8)]
    obs = jnp.concatenate([t["obs"] for t in trajs])
    act = jnp.concatenate([t["act"] for t in trajs])
    nobs = jnp.concatenate([t["next_obs"] for t in trajs])
    params = DYN.update_normalizer(params, obs, act, nobs)
    opt, train_epoch, val_loss = DYN.make_model_trainer(cfg)
    opt_state = opt.init(params)
    l0 = float(val_loss(params, obs, act, nobs))
    for e in range(10):
        params, opt_state, _ = train_epoch(params, opt_state, obs, act,
                                           nobs, jax.random.fold_in(key, e))
    l1 = float(val_loss(params, obs, act, nobs))
    assert l1 < l0 * 0.5, (l0, l1)
    # uniform-prior sampling returns plausible next states
    pred = DYN.predict(params, obs[:16], act[:16], key)
    assert pred.shape == (16, env.obs_dim)
    assert jnp.isfinite(pred).all()


def test_predict_assigned_matches_predict():
    """Sample-then-compute must be a pure reorganisation of the FLOPs:
    under the assignment ``predict`` itself draws, ``predict_assigned``
    returns bit-identical next states (dense select impl on CPU)."""
    env = make_env("pendulum")
    cfg = DYN.EnsembleConfig(env.obs_dim, env.act_dim, hidden=32,
                             n_models=5)
    key = jax.random.key(7)
    params = DYN.init_ensemble(cfg, key)
    obs = jax.random.normal(jax.random.fold_in(key, 1), (24, env.obs_dim))
    act = jax.random.uniform(jax.random.fold_in(key, 2),
                             (24, env.act_dim), minval=-1, maxval=1)
    legacy = DYN.predict(params, obs, act, key)
    idx = DYN.sample_members(params, key, (obs.shape[0],))
    assigned = DYN.predict_assigned(params, obs, act, idx)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(assigned))
    # and the sort/ragged/unsort ref path agrees numerically
    from repro.kernels.gmm import ops as gmm_ops
    n = params["norm"]
    xn = (jnp.concatenate([obs, act], -1) - n["mu_in"]) / n["sig_in"]
    dyn = gmm_ops.ensemble_mlp_select(params["members"], xn, idx,
                                      impl="ref")
    ragged = obs + dyn * n["sig_out"] + n["mu_out"]
    np.testing.assert_allclose(np.asarray(ragged), np.asarray(legacy),
                               atol=1e-5, rtol=1e-5)


def test_imagine_rollout_uses_every_member_and_is_finite():
    env = make_env("pendulum")
    cfg = DYN.EnsembleConfig(env.obs_dim, env.act_dim, hidden=16,
                             n_models=3)
    key = jax.random.key(9)
    params = DYN.init_ensemble(cfg, key)
    pol = PI.init_policy(PolicyConfig(env.obs_dim, env.act_dim, hidden=8),
                         key)
    s0 = env.reset_batch(key, 16)
    traj = jax.jit(lambda p, pp, s, k: DYN.imagine_rollout(
        p, PI.sample_action, pp, s, k, 12, jax.vmap(env.reward)))(
        params, pol, s0, key)
    assert traj["obs"].shape == (12, 16, env.obs_dim)
    for k, v in traj.items():
        assert jnp.isfinite(v).all(), k


def test_trpo_improves_surrogate_and_respects_kl():
    env = make_env("pendulum")
    key = jax.random.key(3)
    pol = PI.init_policy(PolicyConfig(env.obs_dim, env.act_dim, hidden=16),
                         key)
    obs = jax.random.normal(key, (256, env.obs_dim))
    act, pre, lp = PI.sample_with_logp(pol, obs, key)
    adv = jax.random.normal(jax.random.fold_in(key, 1), (256,))
    batch = {"obs": obs, "act_pre": pre, "adv": adv}
    new_pol, info = jax.jit(lambda p, b: TRPO.trpo_step(p, b))(pol, batch)
    kl = float(PI.kl_divergence(pol, new_pol, obs))
    assert kl <= 0.02, kl
    s_new = float(TRPO.surrogate(new_pol, pol, batch))
    assert s_new >= 0.0                      # line search demanded improvement


def test_ppo_step_reduces_loss():
    env = make_env("pendulum")
    key = jax.random.key(4)
    pol = PI.init_policy(PolicyConfig(env.obs_dim, env.act_dim, hidden=16),
                         key)
    obs = jax.random.normal(key, (128, env.obs_dim))
    _, pre, _ = PI.sample_with_logp(pol, obs, key)
    adv = jax.random.normal(jax.random.fold_in(key, 2), (128,))
    batch = {"obs": obs, "act_pre": pre, "adv": adv}
    opt, step = PPO.make_ppo_step(lr=1e-3)
    st = opt.init(pol)
    old = jax.tree.map(lambda x: x, pol)
    l0 = float(PPO.ppo_loss(pol, old, batch))
    p, st, _ = step(pol, st, old, batch)
    for _ in range(5):
        p, st, _ = step(p, st, old, batch)
    l1 = float(PPO.ppo_loss(p, old, batch))
    assert l1 < l0


@pytest.mark.parametrize("algo", ["me-trpo", "me-ppo", "mb-mpo"])
def test_algos_one_improve_step(algo):
    env = make_env("pendulum")
    n_models = 2
    ens_cfg = DYN.EnsembleConfig(env.obs_dim, env.act_dim, hidden=32,
                                 n_models=n_models)
    key = jax.random.key(5)
    model_params = DYN.init_ensemble(ens_cfg, key)
    acfg = AlgoConfig(algo=algo, imagine_batch=8, imagine_horizon=10,
                      n_models=n_models)
    a = make_algo(acfg, PolicyConfig(env.obs_dim, env.act_dim, hidden=16),
                  jax.vmap(env.reward), env.reset_batch)
    state = a.init(key)
    state2, info = a.improve(state, model_params, key)
    assert int(state2["steps"]) == 1
    assert jnp.isfinite(info["imagined_return"])
    # params actually changed
    diffs = [float(jnp.abs(x - y).max()) for x, y in
             zip(jax.tree.leaves(state["policy"]),
                 jax.tree.leaves(state2["policy"]))]
    assert max(diffs) > 0


def test_advantage_computation():
    rews = jnp.ones((5, 3))
    rtg, adv = TRPO.compute_advantages(rews, gamma=0.5)
    np.testing.assert_allclose(np.asarray(rtg[:, 0]),
                               [1.9375, 1.875, 1.75, 1.5, 1.0], rtol=1e-5)
    assert abs(float(adv.mean())) < 1e-5
