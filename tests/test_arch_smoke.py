"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness; plus prefill->decode
consistency against the full-sequence forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api
from repro.models.config import InputShape
from repro.optim.optimizers import adam

SEQ, BATCH = 64, 4


def make_batch(cfg, key, kind="train", seq=SEQ, batch=BATCH):
    kt, ke = jax.random.split(key)
    b = {"tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size)}
    if kind == "train":
        b["labels"] = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        b["enc_embeds"] = jax.random.normal(ke, (batch, seq, cfg.d_model),
                                            jnp.bfloat16)
    if cfg.modality == "vision":
        b["patch_embeds"] = jax.random.normal(
            ke, (batch, seq // 8, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, mesh1):
    cfg = get_config(arch, reduced=True)
    shape = InputShape("t", SEQ, BATCH, "train")
    b = api.build(cfg, mesh1, shape)
    mod = api._mod(cfg)
    key = jax.random.key(0)
    params = mod.init_params(cfg, b.ctx, key)
    opt = adam(cfg.lr)
    opt_state = opt.init(params)
    batch = make_batch(cfg, key)
    p2, o2, m = b.fn(params, opt_state, batch)
    assert jnp.isfinite(m["loss"]), m
    assert jnp.isfinite(m["gnorm"])
    # params updated, shapes preserved
    for a, bb in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == bb.shape
    # a second step decreases nothing catastrophic (still finite)
    p3, o3, m2 = b.fn(p2, o2, batch)
    assert jnp.isfinite(m2["loss"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, mesh1):
    """prefill(S) then decode(token S) must match the full forward's last
    logits on S+1 tokens."""
    cfg = get_config(arch, reduced=True)
    key = jax.random.key(1)
    mod = api.build(cfg, mesh1, InputShape("p", SEQ, BATCH, "prefill"))
    dec = api.build(cfg, mesh1, InputShape("d", SEQ, BATCH, "decode"))
    m = api._mod(cfg)
    params = m.init_params(cfg, mod.ctx, key)

    full = make_batch(cfg, key, kind="prefill", seq=SEQ + 1)
    prefix = dict(full)
    prefix["tokens"] = full["tokens"][:, :SEQ]
    if "enc_embeds" in full:
        # enc context identical for both (cross-attn length must match)
        prefix["enc_embeds"] = full["enc_embeds"][:, :SEQ]

    logits_p, cache = mod.fn(params, prefix)
    assert logits_p.shape[0] == BATCH
    assert jnp.isfinite(logits_p.astype(jnp.float32)).all()

    next_tok = full["tokens"][:, SEQ:SEQ + 1]
    logits_d, cache2 = dec.fn(params, cache, next_tok)
    assert int(cache2["index"]) == SEQ + 1
    assert jnp.isfinite(logits_d.astype(jnp.float32)).all()

    # reference: full forward over S+1 tokens (encdec keeps enc len = SEQ)
    pre2 = api.build(cfg, mesh1, InputShape("p2", SEQ + 1, BATCH, "prefill"))
    full2 = dict(full)
    if "enc_embeds" in full:
        full2["enc_embeds"] = full["enc_embeds"][:, :SEQ]
    if "patch_embeds" in full:
        full2["patch_embeds"] = full["patch_embeds"]
    logits_ref, _ = pre2.fn(params, full2)
    np.testing.assert_allclose(
        np.asarray(logits_d, np.float32), np.asarray(logits_ref, np.float32),
        rtol=0.15, atol=0.15)


@pytest.mark.parametrize("arch", ["glm4_9b", "mixtral_8x7b", "mamba2_2_7b",
                                  "zamba2_7b"])
def test_multi_step_decode(arch, mesh1):
    """Greedy decode 8 tokens from an empty-ish cache stays finite and
    matches teacher-forced forward argmax trajectory."""
    cfg = get_config(arch, reduced=True)
    key = jax.random.key(2)
    S0 = 16
    pre = api.build(cfg, mesh1, InputShape("p", S0, BATCH, "prefill"))
    dec = api.build(cfg, mesh1, InputShape("d", S0 + 8, BATCH, "decode"))
    m = api._mod(cfg)
    params = m.init_params(cfg, pre.ctx, key)
    batch = make_batch(cfg, key, kind="prefill", seq=S0)
    logits, cache = pre.fn(params, batch)
    # re-home the cache into the decode bundle's (larger) cache shapes
    cache = grow_cache(cfg, cache, dec, S0)
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    for _ in range(8):
        logits, cache = dec.fn(params, cache, tok)
        assert jnp.isfinite(logits.astype(jnp.float32)).all()
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None] \
            .astype(jnp.int32)


def grow_cache(cfg, cache, dec_bundle, s0):
    """Pad a prefill cache out to the decode bundle's cache length."""
    tgt = jax.tree.map(lambda x: x, dec_bundle.abstract_args[1])
    out = dict(cache)
    for k in ("k", "v"):
        if k in cache:
            want = tgt[k].shape[2]
            have = cache[k].shape[2]
            if want > have:
                out[k] = jnp.pad(cache[k], ((0, 0), (0, 0), (0, want - have),
                                            (0, 0), (0, 0)))
    if "pos" in cache:
        want = tgt["pos"].shape[0]
        have = cache["pos"].shape[0]
        if want > have:
            out["pos"] = jnp.pad(cache["pos"], (0, want - have),
                                 constant_values=-1)
    return out


def test_int8_kv_decode_matches_bf16(mesh1):
    """int8-quantised KV cache decode agrees with the bf16 cache path."""
    cfg = get_config("glm4_9b", reduced=True)
    key = jax.random.key(9)
    S0 = 32
    pre = api.build(cfg, mesh1, InputShape("p", S0, BATCH, "prefill"))
    pre_q = api.build(cfg, mesh1, InputShape("p", S0, BATCH, "prefill"),
                      kv_int8=True)
    dec = api.build(cfg, mesh1, InputShape("d", S0, BATCH, "decode"))
    dec_q = api.build(cfg, mesh1, InputShape("d", S0, BATCH, "decode"),
                      kv_int8=True)
    params = api._mod(cfg).init_params(cfg, pre.ctx, key)
    batch = {"tokens": jax.random.randint(key, (BATCH, S0), 0,
                                          cfg.vocab_size)}
    lg, cache = pre.fn(params, batch)
    lgq, cacheq = pre_q.fn(params, batch)
    tok = jnp.argmax(lg[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    l1, _ = dec.fn(params, cache, tok)
    l2, _ = dec_q.fn(params, cacheq, tok)
    err = float(jnp.abs(l1.astype(jnp.float32)
                        - l2.astype(jnp.float32)).max())
    assert err < 0.5, err
    agree = float((jnp.argmax(l1[:, :cfg.vocab_size], -1)
                   == jnp.argmax(l2[:, :cfg.vocab_size], -1)).mean())
    assert agree >= 0.75, agree
