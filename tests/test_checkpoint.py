import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_pytree, restore, save_pytree


def make_tree(key):
    return {"w": [jax.random.normal(key, (4, 3)),
                  jnp.zeros((3,), jnp.bfloat16)],
            "step": jnp.asarray(7, jnp.int32),
            "nested": {"a": jnp.ones((2, 2))}}


def test_roundtrip(tmp_path):
    tree = make_tree(jax.random.key(0))
    save_pytree(tmp_path / "ck", tree)
    out = load_pytree(tmp_path / "ck", tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_steps_and_retention(tmp_path):
    tree = make_tree(jax.random.key(1))
    for s in (10, 20, 30, 40):
        save_pytree(tmp_path / "run", tree, step=s, keep=2)
    assert latest_step(tmp_path / "run") == 40
    out, step = restore(tmp_path / "run", tree)
    assert step == 40
    # retention: only 2 newest kept
    steps = sorted(p.name for p in (tmp_path / "run").glob("step_*"))
    assert len(steps) == 2


def test_shape_mismatch_raises(tmp_path):
    tree = make_tree(jax.random.key(2))
    save_pytree(tmp_path / "ck", tree)
    bad = dict(tree)
    bad["w"] = [jnp.zeros((5, 3)), tree["w"][1]]
    with pytest.raises(AssertionError):
        load_pytree(tmp_path / "ck", bad)


# ------------------------------------------- crash-atomic writes (PR 7)
def test_restore_skips_truncated_snapshot(tmp_path):
    """A snapshot torn mid-write (truncated arrays.npz — only possible
    for pre-atomic writers or filesystem damage) must not poison
    restarts: restore falls back to the newest COMPLETE step."""
    tree = make_tree(jax.random.key(3))
    save_pytree(tmp_path / "run", tree, step=1)
    save_pytree(tmp_path / "run", tree, step=2)
    npz = tmp_path / "run" / "step_000000002" / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[:10])          # truncate
    out, step = restore(tmp_path / "run", tree)
    assert step == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_restore_raises_when_nothing_complete(tmp_path):
    tree = make_tree(jax.random.key(4))
    save_pytree(tmp_path / "run", tree, step=1)
    npz = tmp_path / "run" / "step_000000001" / "arrays.npz"
    npz.write_bytes(b"not a checkpoint")
    with pytest.raises(FileNotFoundError, match="no complete checkpoint"):
        restore(tmp_path / "run", tree)


def test_tmp_leftovers_are_invisible_and_swept(tmp_path):
    """A writer SIGKILLed mid-snapshot leaves only a ``step_*.tmp`` dir.
    It must not crash ``latest_step`` (the int parse used to choke on
    it), must be skipped by ``restore``, and gets swept by the next
    successful save."""
    tree = make_tree(jax.random.key(5))
    save_pytree(tmp_path / "run", tree, step=1)
    orphan = tmp_path / "run" / "step_000000002.tmp"
    orphan.mkdir()
    (orphan / "arrays.npz").write_bytes(b"partial")
    assert latest_step(tmp_path / "run") == 1
    _, step = restore(tmp_path / "run", tree)
    assert step == 1
    save_pytree(tmp_path / "run", tree, step=3)
    assert not orphan.exists()                      # swept
    assert latest_step(tmp_path / "run") == 3
