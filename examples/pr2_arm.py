"""Section 5.5 in simulation: contact-style manipulation on the 7-DOF arm.

Runs asynch MBRL on the three PR2-style tasks (reach / shape-match /
lego-stack) with the paper's exact reward r(d) = -d^2 - log(d^2 + 1e-5)
and 10 Hz torque control, and reports the final end-effector distance and
the simulated run time — the paper's result is task success within ~10
minutes of robot time (Fig. 7)."""
import jax
import jax.numpy as jnp

from repro.core import AsyncTrainer, RunConfig
from repro.envs import make_env
from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig, make_algo
from repro.mbrl import policy as PI


def final_distance(env, params, key, n=8):
    def one(k):
        tr = env.rollout(k, lambda p, s, kk: PI.deterministic_action(p, s),
                         params)
        return env.distance(tr["obs"][-1])
    return float(jnp.mean(jax.vmap(one)(jax.random.split(key, n))))


def main():
    for task in ("pr2_reach", "pr2_shape_match", "pr2_lego_stack"):
        env = make_env(task)
        ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=128,
                             n_models=3)
        pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=64)
        acfg = AlgoConfig(algo="me-trpo", imagine_batch=48,
                          imagine_horizon=50, n_models=3)
        algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
        tr = AsyncTrainer(env, ens, algo,
                          RunConfig(total_trajs=20, seed=0))
        trace = tr.run()
        d = final_distance(env, tr.policy_worker.state["policy"],
                           jax.random.key(123))
        mins = trace[-1]["time"] / 60.0
        print(f"{task:18s}: final distance {d:.3f} m after "
              f"{mins:.1f} simulated minutes "
              f"(best return {max(r['eval_return'] for r in trace):.1f})")


if __name__ == "__main__":
    main()
