"""Dyna with a transformer world model: the scaled path of DESIGN.md §3.

The MLP ensemble of the paper is swapped for a token-level decoder LM
behind the SAME ``predict(params, obs, act, key)`` contract; imagination
becomes prefill + greedy decode — the serve steps the production dry-run
lowers at pod scale. This example trains the world model on pendulum
transitions and takes ME-TRPO policy steps against it.
"""
import jax
import jax.numpy as jnp

from repro.envs import make_env
from repro.mbrl import policy as PI
from repro.mbrl.algos import AlgoConfig, MEAlgo
from repro.mbrl.policy import PolicyConfig
from repro.mbrl.wm_dynamics import WMConfig, WorldModelDynamics


def main():
    env = make_env("pendulum")
    key = jax.random.key(0)
    wm = WorldModelDynamics(WMConfig(env.obs_dim, env.act_dim, bins=33,
                                     d_model=96, num_layers=2), key)
    pol = PI.init_policy(PolicyConfig(env.obs_dim, env.act_dim, hidden=16),
                         key)
    trajs = [env.rollout(jax.random.fold_in(key, i), PI.sample_action, pol)
             for i in range(8)]
    obs = jnp.concatenate([t["obs"] for t in trajs])
    act = jnp.concatenate([t["act"] for t in trajs])
    nobs = jnp.concatenate([t["next_obs"] for t in trajs])
    wm.update_normalizer(jnp.concatenate([obs, nobs]))

    def mse():
        pred = wm.predict(obs[:128], act[:128], key)
        return float(jnp.mean((pred - nobs[:128]) ** 2))

    print(f"world-model MSE before training: {mse():.3f}")
    for e in range(15):
        loss = wm.train_epoch(obs, act, nobs, jax.random.fold_in(key, e))
    print(f"after 15 epochs: token loss {loss:.3f}, MSE {mse():.3f}")

    acfg = AlgoConfig(algo="me-trpo", imagine_batch=16, imagine_horizon=10)
    algo = MEAlgo(acfg, PolicyConfig(env.obs_dim, env.act_dim, hidden=16),
                  jax.vmap(env.reward), env.reset_batch,
                  predict_fn=wm.predict_fn())
    state = algo.init(key)
    for i in range(5):
        state, info = algo.improve(state, wm.params, jax.random.fold_in(key, i))
        print(f"policy step {i}: imagined return "
              f"{float(info['imagined_return']):.1f}")
    print("the policy-improvement worker ran entirely on transformer "
          "imagination (prefill + decode).")


if __name__ == "__main__":
    main()
