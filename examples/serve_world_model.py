"""End-to-end serving driver: batched requests against a small world model.

This is the policy-improvement worker's consumption pattern scaled down:
prefill a batch of observation-history prompts, then autoregressively
decode continuations with the KV cache — the same prefill/decode steps the
production dry-run lowers at (32, 32768) / (128, 32768).

This lock-step flow (one batch in, the whole batch decodes in unison) is
the pedagogical baseline; the production path is the continuous-batching
serve tier in ``repro.serve`` (``python -m repro.serve``), which admits
and retires requests mid-flight and hot-swaps weights from a live
ParameterServer.
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models import api
from repro.models.config import InputShape

PROMPT, GEN, BATCH = 48, 16, 8


def main():
    cfg = get_config("glm4-9b", reduced=True)
    mesh = make_smoke_mesh()
    pre = api.build(cfg, mesh, InputShape("p", PROMPT, BATCH, "prefill"))
    dec = api.build(cfg, mesh, InputShape("d", PROMPT + GEN, BATCH,
                                          "decode"))
    mod = api._mod(cfg)
    # independent streams for weights and request tokens (reusing one key
    # would correlate the served prompts with the model init)
    key_params, key_prompts = jax.random.split(jax.random.key(0))
    params = mod.init_params(cfg, pre.ctx, key_params)

    # batched requests (token prompts)
    prompts = jax.random.randint(key_prompts, (BATCH, PROMPT), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    logits, cache = pre.fn(params, {"tokens": prompts})
    # grow the cache to the decode bundle's length (pos pads with -1=empty)
    cache = api.grow_cache(cache, dec.abstract_args[1]["k"].shape[2])
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.perf_counter()
    for _ in range(GEN - 1):
        logits, cache = dec.fn(params, cache, tok)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None] \
            .astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"served {BATCH} requests: prompt {PROMPT} tokens, "
          f"generated {GEN} tokens each")
    print(f"prefill: {t_prefill * 1e3:.1f} ms   "
          f"decode: {t_decode / (GEN - 1) * 1e3:.1f} ms/token (CPU)")
    print("sample continuation token ids:", out[0].tolist())


if __name__ == "__main__":
    main()
