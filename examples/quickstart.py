"""Quickstart: asynchronous ME-TRPO on the pendulum in ~a minute (CPU).

The three workers (data collection / model learning / policy improvement)
run under the deterministic discrete-event engine; the x-axis is the
simulated ROBOT time (Fig. 2 methodology), so you can see directly that
the run time is ~ the data-collection time.
"""
import jax

from repro.core import AsyncTrainer, RunConfig
from repro.envs import make_env
from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig, make_algo


def main():
    env = make_env("pendulum")
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=64, n_models=3)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=32)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=48, imagine_horizon=40,
                      n_models=3)
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)

    trainer = AsyncTrainer(env, ens, algo, RunConfig(total_trajs=12, seed=0))
    trace = trainer.run()

    print(f"{'robot-time':>10s} {'trajs':>6s} {'eval return':>12s}")
    for row in trace:
        print(f"{row['time']:10.1f} {row['trajs']:6d} "
              f"{row['eval_return']:12.1f}")
    print("\ntotal simulated robot time:", trace[-1]["time"], "s "
          "(= collection time — the async property)")


if __name__ == "__main__":
    main()
