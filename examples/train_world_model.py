"""End-to-end training driver: pre-train a transformer world model for a
few hundred steps on synthetic trajectory-token data.

Model: a scaled-down GLM4-family decoder (~10M params by default; pass
--big for ~100M — slower on CPU, the intended pod workload). Data: an
in-repo synthetic 'tokenised dynamics' stream — a mixture of periodic
patterns the model must learn to predict, standing in for the
trajectory tokeniser of a Dyna-style world model.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_smoke_mesh
from repro.models import api
from repro.models.config import InputShape, ModelConfig
from repro.optim.optimizers import adam

SMALL = ModelConfig(name="wm-10m", family="dense", num_layers=4,
                    d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                    vocab_size=2048)
BIG = ModelConfig(name="wm-100m", family="dense", num_layers=12,
                  d_model=768, num_heads=12, num_kv_heads=4, d_ff=3072,
                  vocab_size=8192)


def synth_batch(key, batch, seq, vocab):
    """Deterministic-ish dynamics tokens: s_{t+1} = f(s_t, a_t) mod vocab."""
    k1, k2 = jax.random.split(key)
    s0 = jax.random.randint(k1, (batch, 1), 0, vocab)
    acts = jax.random.randint(k2, (batch, seq), 0, 7)

    def step(s, a):
        s2 = (s * 31 + a * 131 + 17) % vocab
        return s2, s2

    _, toks = jax.lax.scan(lambda c, a: step(c, a), s0[:, 0],
                           jnp.swapaxes(acts, 0, 1))
    toks = jnp.swapaxes(toks, 0, 1)
    return {"tokens": toks, "labels": toks}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    cfg = BIG if args.big else SMALL
    mesh = make_smoke_mesh()
    shape = InputShape("wm", args.seq, args.batch, "train")
    bundle = api.build(cfg, mesh, shape)
    key = jax.random.key(0)
    from repro.models import lm as LM
    params = LM.init_params(cfg, bundle.ctx, key)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"world model {cfg.name}: {n/1e6:.1f}M params")
    opt = adam(3e-3)
    opt_state = opt.init(params)
    t0 = time.perf_counter()
    for step in range(args.steps):
        key, k = jax.random.split(key)
        batch = synth_batch(k, args.batch, args.seq, cfg.vocab_size)
        params, opt_state, m = bundle.fn(params, opt_state, batch)
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"({(time.perf_counter()-t0)/(step+1):.2f}s/step)",
                  flush=True)
    print("final loss should approach 0 — the dynamics are deterministic.")


if __name__ == "__main__":
    main()
