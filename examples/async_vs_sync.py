"""Figure-2-in-miniature: the same algorithm, asynchronous vs sequential.

Reproduces the paper's headline claim — the asynchronous framework brings
the run time down to the data-collection time, while the sequential
version pays for model fitting and policy optimisation serially — and
the Fig. 4 follow-up: a fleet of parallel collectors
(``AsyncTrainer(n_collectors=N)``) shrinks that collection time again,
reaching the same global trajectory criterion in fewer policy steps."""
import jax

from repro.core import AsyncTrainer, RunConfig, SequentialTrainer
from repro.envs import make_env
from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig, make_algo


def build(env):
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=64, n_models=3)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=32)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=48, imagine_horizon=40,
                      n_models=3)
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
    return ens, algo


def main():
    env = make_env("pendulum")
    rc = RunConfig(total_trajs=10, seed=0)

    ens, algo = build(env)
    t_async = AsyncTrainer(env, ens, algo, rc).run()
    ens, algo = build(env)
    fleet = AsyncTrainer(env, ens, algo, rc, n_collectors=4)
    t_fleet = fleet.run()
    fleet_steps = fleet.policy_worker.steps
    ens, algo = build(env)
    t_seq = SequentialTrainer(env, ens, algo, rc).run()

    ta, tf, ts = (t_async[-1]["time"], t_fleet[-1]["time"],
                  t_seq[-1]["time"])
    print(f"async          : {ta:8.1f}s simulated robot time "
          f"(best return {max(r['eval_return'] for r in t_async):.1f})")
    print(f"async, fleet=4 : {tf:8.1f}s simulated robot time "
          f"(criterion reached after {fleet_steps} policy steps; "
          f"best return {max(r['eval_return'] for r in t_fleet):.1f})")
    print(f"sequential     : {ts:8.1f}s simulated robot time "
          f"(best return {max(r['eval_return'] for r in t_seq):.1f})")
    print(f"wall-clock speed-up: {ts / ta:.2f}x async, {ts / tf:.2f}x "
          "with the fleet (paper reports >10x on quadruped locomotion)")


if __name__ == "__main__":
    main()
