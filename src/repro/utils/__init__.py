from repro.utils.tree import (
    tree_add, tree_scale, tree_zeros_like, tree_norm, tree_dot,
    tree_size, tree_cast,
)
