"""Compile-count instrumentation for jitted hot-path functions.

``trace_counted(fn, **jit_kw)`` wraps ``fn`` in ``jax.jit`` but counts how
many times the Python body is traced (each trace == one XLA compile for a
new input signature). The async hot path is built on the invariant that
its stepped functions trace exactly once after warmup; the regression
test (tests/test_hotpath.py) and the hotpath benchmark read
``.trace_count`` to enforce it.
"""
from __future__ import annotations

import jax


class TraceCounted:
    """Callable wrapping ``jax.jit(fn)`` that records trace events."""

    def __init__(self, fn, **jit_kw):
        self.trace_count = 0
        self.__name__ = getattr(fn, "__name__", "trace_counted")

        def counted(*args, **kwargs):
            self.trace_count += 1
            return fn(*args, **kwargs)

        counted.__name__ = self.__name__
        self._jitted = jax.jit(counted, **jit_kw)

    def __call__(self, *args, **kwargs):
        return self._jitted(*args, **kwargs)


def trace_counted(fn, **jit_kw) -> TraceCounted:
    return TraceCounted(fn, **jit_kw)


def jit_cache_size(fn) -> int:
    """Number of compiled entries a jitted callable holds, or -1 when it
    cannot be determined. Works for plain ``jax.jit`` objects (their
    ``_cache_size()``) and :class:`TraceCounted` wrappers (their exact
    ``trace_count``). The chaos invariant monitor (repro.chaos) reads
    this through worker ``compile_count()`` methods to assert
    zero-retrace-after-warmup DURING a run, not just in tests."""
    if fn is None:
        return 0
    if isinstance(fn, TraceCounted):
        return int(fn.trace_count)
    try:
        return int(fn._cache_size())
    except Exception:
        return -1
