"""TRPO: conjugate-gradient natural step + KL line search [Schulman 15].

Operates on imagined (model) or real batches: dict with obs (N, D),
act_pre (N, A), adv (N,), plus old params for the ratio."""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.mbrl import policy as PI
from repro.utils.tree import tree_add, tree_dot, tree_scale, tree_zeros_like


def surrogate(params, params_old, batch):
    lp = PI.log_prob(params, batch["obs"], batch["act_pre"])
    lp_old = PI.log_prob(params_old, batch["obs"], batch["act_pre"])
    ratio = jnp.exp(lp - lp_old)
    return (ratio * batch["adv"]).mean()


def _cg(hvp, g, iters=10, damping=1e-2):
    x = tree_zeros_like(g)
    r = g
    p = g
    rs = tree_dot(r, r)

    def body(carry, _):
        x, r, p, rs = carry
        hp = tree_add(hvp(p), tree_scale(p, damping))
        alpha = rs / (tree_dot(p, hp) + 1e-10)
        x = tree_add(x, tree_scale(p, alpha))
        r = tree_add(r, tree_scale(hp, -alpha))
        rs_new = tree_dot(r, r)
        p = tree_add(r, tree_scale(p, rs_new / (rs + 1e-10)))
        return (x, r, p, rs_new), None

    (x, *_), _ = jax.lax.scan(body, (x, r, p, rs), None, length=iters)
    return x


def trpo_step(params, batch, *, max_kl=0.01, cg_iters=10, backtrack=10,
              backtrack_coef=0.8):
    """One TRPO update. Returns (new_params, info)."""
    params_old = jax.tree.map(lambda x: x, params)
    g = jax.grad(surrogate)(params, params_old, batch)

    def kl_fn(p):
        return PI.kl_divergence(params_old, p, batch["obs"])

    def hvp(v):
        return jax.jvp(jax.grad(kl_fn), (params,), (v,))[1]

    step_dir = _cg(hvp, g, iters=cg_iters)
    shs = tree_dot(step_dir, hvp(step_dir))
    lm = jnp.sqrt(jnp.maximum(shs, 1e-10) / (2 * max_kl))
    full_step = tree_scale(step_dir, 1.0 / jnp.maximum(lm, 1e-10))
    expected = tree_dot(g, full_step)

    def try_step(frac):
        cand = tree_add(params, tree_scale(full_step, frac))
        s = surrogate(cand, params_old, batch)
        kl = kl_fn(cand)
        ok = (kl <= max_kl * 1.5) & (s > 0)
        return cand, ok, s, kl

    def body(carry, frac):
        best, found = carry
        cand, ok, s, kl = try_step(frac)
        take = ok & (~found)
        best = jax.tree.map(lambda b, c: jnp.where(take, c, b), best, cand)
        return (best, found | ok), (s, kl)

    fracs = backtrack_coef ** jnp.arange(backtrack)
    (new_params, found), (ss, kls) = jax.lax.scan(body, (params, False),
                                                  fracs)
    info = {"found": found, "surrogate": ss[0], "kl": kls[0],
            "expected_improve": expected}
    return new_params, info


def compute_advantages(rews, gamma=0.99, lam=0.97, values=None):
    """Discounted reward-to-go baseline-centred advantages.
    rews: (H, B). Without a value net, uses return-to-go minus its
    per-timestep batch mean (the ME-TRPO [10] setup uses a linear baseline;
    the batch-mean baseline is the variance-reduction workhorse here)."""
    H = rews.shape[0]

    def body(carry, r):
        g = r + gamma * carry
        return g, g

    _, rtg = jax.lax.scan(body, jnp.zeros_like(rews[0]), rews[::-1])
    rtg = rtg[::-1]                       # (H, B)
    adv = rtg - rtg.mean(axis=1, keepdims=True)
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    return rtg, adv
