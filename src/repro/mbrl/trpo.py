"""TRPO: conjugate-gradient natural step + KL line search [Schulman 15].

Operates on imagined (model) or real batches: dict with obs (N, D),
act_pre (N, A), adv (N,), plus old params for the ratio."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.mbrl import policy as PI
from repro.utils.tree import tree_add, tree_dot, tree_scale, tree_zeros_like


def surrogate(params, params_old, batch):
    lp = PI.log_prob(params, batch["obs"], batch["act_pre"])
    lp_old = PI.log_prob(params_old, batch["obs"], batch["act_pre"])
    ratio = jnp.exp(lp - lp_old)
    return (ratio * batch["adv"]).mean()


def _cg(hvp, g, iters=10, damping=1e-2):
    x = tree_zeros_like(g)
    r = g
    p = g
    rs = tree_dot(r, r)

    def body(carry, _):
        x, r, p, rs = carry
        hp = tree_add(hvp(p), tree_scale(p, damping))
        alpha = rs / (tree_dot(p, hp) + 1e-10)
        x = tree_add(x, tree_scale(p, alpha))
        r = tree_add(r, tree_scale(hp, -alpha))
        rs_new = tree_dot(r, r)
        p = tree_add(r, tree_scale(p, rs_new / (rs + 1e-10)))
        return (x, r, p, rs_new), None

    (x, *_), _ = jax.lax.scan(body, (x, r, p, rs), None, length=iters)
    return x


def trpo_step(params, batch, *, max_kl=0.01, cg_iters=10, backtrack=10,
              backtrack_coef=0.8, fvp_subsample=4):
    """One TRPO update. Returns (new_params, info).

    This sits on the policy-improvement hot path, so every constant of the
    frozen pre-step policy (mean actions, log-probs, variances) is computed
    ONCE up front instead of re-running the old network inside each CG /
    line-search evaluation. The CG step direction uses the Gauss-Newton
    Fisher-vector product (one jvp + one vjp of the mean network) — exact
    at the pre-step point, where the KL Hessian's residual term vanishes —
    on every ``fvp_subsample``-th row, the standard TRPO trick (Schulman
    15 uses a subsample factor of 5) since the Fisher estimate needs far
    fewer rows than the gradient. The KL trust region is still enforced on
    the FULL batch by the line search, which evaluates all backtrack
    candidates as one vmapped batch and takes the first acceptable one
    (exactly what the sequential scan accepted)."""
    obs = batch["obs"]
    mu_old = PI.mean_action(params, obs)
    ls_old = params["log_std"]
    v_old = jnp.exp(2 * ls_old)
    lp_old = PI.log_prob(params, obs, batch["act_pre"])

    def surrogate_new(p):
        lp = PI.log_prob(p, obs, batch["act_pre"])
        return (jnp.exp(lp - lp_old) * batch["adv"]).mean()

    def kl_new(p):
        """KL(old || p) with the old policy's stats precomputed."""
        mu1 = PI.mean_action(p, obs)
        ls1 = p["log_std"]
        v1 = jnp.exp(2 * ls1)
        return (ls1 - ls_old + (v_old + (mu_old - mu1) ** 2) / (2 * v1)
                - 0.5).sum(-1).mean()

    g = jax.grad(surrogate_new)(params)

    # keep >=256 rows in the Fisher estimate: tiny batches subsampled
    # further yield directions the line search rejects outright
    stride = max(1, min(fvp_subsample, obs.shape[0] // 256))
    obs_fvp = obs[::stride]
    n_fvp = obs_fvp.shape[0]
    mu_fvp = lambda p: PI.mean_action(p, obs_fvp)
    _, vjp_mu = jax.vjp(mu_fvp, params)

    def fvp(v):
        jv = jax.jvp(mu_fvp, (params,), (v,))[1]
        out = vjp_mu(jv / v_old / n_fvp)[0]
        # log_std block of the Gaussian Fisher is diagonal 2; mean/log_std
        # cross terms vanish at the pre-step point
        return {**out, "log_std": out["log_std"] + 2.0 * v["log_std"]}

    step_dir = _cg(fvp, g, iters=cg_iters)
    shs = tree_dot(step_dir, fvp(step_dir))
    lm = jnp.sqrt(jnp.maximum(shs, 1e-10) / (2 * max_kl))
    full_step = tree_scale(step_dir, 1.0 / jnp.maximum(lm, 1e-10))
    expected = tree_dot(g, full_step)

    fracs = backtrack_coef ** jnp.arange(backtrack)

    def eval_frac(frac):
        cand = tree_add(params, tree_scale(full_step, frac))
        return surrogate_new(cand), kl_new(cand)

    ss, kls = jax.vmap(eval_frac)(fracs)
    oks = (kls <= max_kl * 1.5) & (ss > 0)
    found = oks.any()
    frac = jnp.where(found, fracs[jnp.argmax(oks)], 0.0)
    stepped = tree_add(params, tree_scale(full_step, frac))
    # select, don't scale-by-zero: a NaN/Inf step direction (diverged
    # rollout) must leave the pre-step params untouched when rejected
    new_params = jax.tree.map(lambda p, q: jnp.where(found, q, p),
                              params, stepped)
    info = {"found": found, "surrogate": ss[0], "kl": kls[0],
            "expected_improve": expected}
    return new_params, info


def compute_advantages(rews, gamma=0.99, lam=0.97, values=None):
    """Discounted reward-to-go baseline-centred advantages.
    rews: (H, B). Without a value net, uses return-to-go minus its
    per-timestep batch mean (the ME-TRPO [10] setup uses a linear baseline;
    the batch-mean baseline is the variance-reduction workhorse here)."""
    H = rews.shape[0]

    def body(carry, r):
        g = r + gamma * carry
        return g, g

    _, rtg = jax.lax.scan(body, jnp.zeros_like(rews[0]), rews[::-1])
    rtg = rtg[::-1]                       # (H, B)
    adv = rtg - rtg.mean(axis=1, keepdims=True)
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    return rtg, adv
