"""Model-free TRPO/PPO baselines (the paper's dotted lines in Figs. 2-3).

On-policy: collect a batch of real trajectories per iteration, then take
TRPO or several PPO steps. Virtual-time accounting matches the MBRL
engines (collection = horizon * dt per trajectory)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.runtime import RunConfig, _Recorder
from repro.mbrl import policy as PI
from repro.mbrl import ppo as PPO
from repro.mbrl import trpo as TRPO


class ModelFreeTrainer:
    def __init__(self, env, pol_cfg, run_cfg: Optional[RunConfig] = None, *,
                 algo: str = "ppo", trajs_per_iter: int = 4,
                 ppo_epochs: int = 10, gamma: float = 0.99):
        self.env = env
        run_cfg = RunConfig() if run_cfg is None else run_cfg
        self.rc = run_cfg
        self.algo = algo
        self.trajs_per_iter = trajs_per_iter
        self.ppo_epochs = ppo_epochs
        self.gamma = gamma
        key = jax.random.key(run_cfg.seed)
        self._key, k0, self._keval = jax.random.split(key, 3)
        self.params = PI.init_policy(pol_cfg, k0)
        if algo == "ppo":
            self._opt, self._ppo_step = PPO.make_ppo_step()
            self.opt_state = self._opt.init(self.params)
        self.recorder = _Recorder(env, run_cfg.eval_rollouts)
        self._collect = jax.jit(self._collect_impl)

    def _collect_impl(self, params, key):
        def one(k):
            k0, k = jax.random.split(k)
            s0 = self.env.reset(k0)

            def step(s, kk):
                a, pre, lp = PI.sample_with_logp(params, s, kk)
                s2, r = self.env.step(s, a)
                return s2, (s, pre, r)

            _, (obs, pre, rew) = jax.lax.scan(
                step, s0, jax.random.split(k, self.env.horizon))
            return obs, pre, rew

        obs, pre, rew = jax.vmap(one)(
            jax.random.split(key, self.trajs_per_iter))
        # (n, H, ·) -> (H, n, ·) for advantage computation
        return (jnp.swapaxes(obs, 0, 1), jnp.swapaxes(pre, 0, 1),
                jnp.swapaxes(rew, 0, 1))

    def run(self):
        rc = self.rc
        t = 0.0
        collected = 0
        traj_t = self.env.horizon * self.env.dt
        while collected < rc.total_trajs:
            self._key, k = jax.random.split(self._key)
            obs, pre, rew = self._collect(self.params, k)
            collected += self.trajs_per_iter
            t += traj_t * self.trajs_per_iter
            _, adv = TRPO.compute_advantages(rew, gamma=self.gamma)
            flat = lambda x: x.reshape((-1,) + x.shape[2:])
            batch = {"obs": flat(obs), "act_pre": flat(pre),
                     "adv": adv.reshape(-1)}
            if self.algo == "trpo":
                self.params, _ = TRPO.trpo_step(self.params, batch)
                t += rc.policy_step_time
            else:
                old = jax.tree.map(lambda x: x, self.params)
                for _ in range(self.ppo_epochs):
                    self.params, self.opt_state, _ = self._ppo_step(
                        self.params, self.opt_state, old, batch)
                    t += rc.policy_step_time
            self._keval, k2 = jax.random.split(self._keval)
            self.recorder.record(t, collected, self.params, k2)
        return self.recorder.trace
