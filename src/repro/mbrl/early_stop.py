"""EMA-validation early stopping (paper §4 'Model learning' and §5.4).

Training stops when the CURRENT validation loss exceeds the exponential
moving average of past validation losses; the average resets whenever new
samples arrive. Lower ``weight`` = more aggressive stopping (Fig. 5a)."""
from __future__ import annotations


class EMAEarlyStop:
    def __init__(self, weight: float = 0.9, enabled: bool = True):
        assert 0.0 < weight < 1.0
        self.weight = weight
        self.enabled = enabled
        self.reset()

    def reset(self):
        self.ema = None
        self.stopped = False

    def update(self, val_loss: float) -> bool:
        """Feed one epoch's validation loss; returns stopped flag."""
        if self.ema is None:
            self.ema = val_loss
            return False
        if self.enabled and val_loss > self.ema:
            self.stopped = True
        self.ema = self.weight * self.ema + (1 - self.weight) * val_loss
        return self.stopped
