"""Model-based algorithm 'policy improvement steps' (Alg. 3, Step op).

Each algorithm exposes::

  init(key)                                   -> algo_state
  improve(algo_state, model_params, key)      -> (algo_state, info)

where ``improve`` is the MINIMAL unit of work the paper assigns to the
policy-improvement worker: sample a batch of imaginary trajectories from
the current dynamics model and take ONE policy-gradient (TRPO/PPO) step.

* ME-TRPO  [10]: imagined rollouts from the ensemble -> TRPO step.
* ME-PPO   [paper §5.1]: same, PPO clipped step.
* MB-MPO   [4]: per-model inner VPG adaptation, outer PPO step on the
  post-adaptation surrogate (meta-policy optimization).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.mbrl import dynamics as DYN
from repro.mbrl import policy as PI
from repro.mbrl import ppo as PPO
from repro.mbrl import trpo as TRPO
from repro.optim.optimizers import adam, apply_updates


@dataclasses.dataclass(frozen=True)
class AlgoConfig:
    algo: str = "me-trpo"           # me-trpo | me-ppo | mb-mpo
    imagine_batch: int = 64         # parallel imagined starts
    imagine_horizon: int = 50
    gamma: float = 0.99
    max_kl: float = 0.01
    ppo_lr: float = 3e-4
    inner_lr: float = 0.05          # MB-MPO inner adaptation step size
    n_models: int = 5


def _rollout_with_logp(model_params, pol_params, s0, key, H, reward_fn,
                       predict_fn=None, *, fused=True):
    """Imagined rollout recording pre-tanh actions for exact densities.

    ``predict_fn=None`` is the ensemble fast path: member assignments
    AND policy noise for the whole horizon are drawn up front, and each
    step is ONE fused ``DYN.step_fused`` dispatch — policy head +
    assigned-member dynamics in a single kernel, no K* ensemble
    overcompute and no per-step sort inside the scan. ``fused=False``
    keeps the legacy two-call step (``PI.sample_with_logp`` +
    ``DYN.predict_assigned``) for parity/benchmark comparison. A
    non-None ``predict_fn`` with the ``(params, obs, act, key)``
    contract swaps in any other world model (e.g. ``wm_dynamics``)."""
    if predict_fn is None:
        ka, kp = jax.random.split(key)
        members = DYN.sample_members(model_params, kp, (H, s0.shape[0]))

        if fused:
            act_dim = pol_params["w"][-1].shape[1]
            eps = DYN.hoisted_noise(ka, H, s0.shape[0], act_dim)
            plan = DYN.horizon_plan(model_params, members)

            def step(carry, xs):
                e, midx, pl_ = xs
                s = carry
                s2, a, pre = DYN.step_fused(model_params, pol_params, s,
                                            e, midx, plan=pl_)
                r = reward_fn(s, a, s2)
                return s2, (s, pre, r)

            _, (obs, pre, rew) = jax.lax.scan(
                step, s0, (eps, members, plan))
            return obs, pre, rew

        def step(carry, xs):
            k, midx = xs
            s = carry
            a, pre, lp = PI.sample_with_logp(pol_params, s, k)
            s2 = DYN.predict_assigned(model_params, s, a, midx)
            r = reward_fn(s, a, s2)
            return s2, (s, pre, r)

        _, (obs, pre, rew) = jax.lax.scan(
            step, s0, (jax.random.split(ka, H), members))
        return obs, pre, rew

    def step(carry, k):
        s = carry
        ka, kp = jax.random.split(k)
        a, pre, lp = PI.sample_with_logp(pol_params, s, ka)
        s2 = predict_fn(model_params, s, a, kp)
        r = reward_fn(s, a, s2)
        return s2, (s, pre, r)

    _, (obs, pre, rew) = jax.lax.scan(step, s0, jax.random.split(key, H))
    return obs, pre, rew


def _flat_batch(obs, pre, rew, gamma):
    rtg, adv = TRPO.compute_advantages(rew, gamma=gamma)
    flat = lambda x: x.reshape((-1,) + x.shape[2:])
    return {"obs": flat(obs), "act_pre": flat(pre), "adv": adv.reshape(-1)}


class _MeshMixin:
    """Shared role-mesh hook: ``configure_mesh`` pins imagined-rollout
    batches (and everything downstream: advantages, TRPO statistics) to
    the policy sub-mesh's batch axis. Params stay replicated — the worker
    places them (core/workers.py). Without a mesh, ``_shard_batch`` is
    the identity and the jitted step is unchanged."""

    _batch_sharding = None

    def configure_mesh(self, mesh, batch_axis: str | None = None) -> None:
        from jax.sharding import NamedSharding, PartitionSpec
        axis = batch_axis or mesh.axis_names[0]
        self._batch_sharding = NamedSharding(mesh, PartitionSpec(axis))
        # drop any traces compiled before the mesh was known
        self._improve = jax.jit(self._improve_impl)

    def _shard_batch(self, x):
        if self._batch_sharding is None:
            return x
        return jax.tree.map(
            lambda v: jax.lax.with_sharding_constraint(
                v, self._batch_sharding), x)


class MEAlgo(_MeshMixin):
    """ME-TRPO / ME-PPO policy improvement."""

    def __init__(self, cfg: AlgoConfig, pol_cfg: PI.PolicyConfig, reward_fn,
                 init_state_fn, *, predict_fn=None, mesh=None,
                 batch_axis=None):
        self.cfg = cfg
        self.pol_cfg = pol_cfg
        self.reward_fn = reward_fn
        self.init_state_fn = init_state_fn  # key, n -> (n, obs_dim)
        self.predict_fn = predict_fn        # None = ensemble fast path;
        #                                     swap in a world model here
        if cfg.algo == "me-ppo":
            self._ppo_opt, self._ppo_step = PPO.make_ppo_step(cfg.ppo_lr)
        self._improve = jax.jit(self._improve_impl)
        if mesh is not None:
            self.configure_mesh(mesh, batch_axis)

    def init(self, key):
        pol = PI.init_policy(self.pol_cfg, key)
        state = {"policy": pol, "steps": jnp.zeros((), jnp.int32)}
        if self.cfg.algo == "me-ppo":
            state["opt"] = self._ppo_opt.init(pol)
        return state

    def _improve_impl(self, state, model_params, key):
        cfg = self.cfg
        k0, k1 = jax.random.split(key)
        # shard imagined starts over the policy sub-mesh: the rollout scan
        # carries the batch dim, so imagination runs data-parallel
        s0 = self._shard_batch(self.init_state_fn(k0, cfg.imagine_batch))
        obs, pre, rew = _rollout_with_logp(
            model_params, state["policy"], s0, k1, cfg.imagine_horizon,
            self.reward_fn, self.predict_fn)
        # TRPO/PPO statistics (advantages, Fisher-vector products, line
        # search) computed over the sharded flat batch
        batch = self._shard_batch(_flat_batch(obs, pre, rew, cfg.gamma))
        info = {"imagined_return": rew.sum(0).mean()}
        if cfg.algo == "me-trpo":
            new_pol, tinfo = TRPO.trpo_step(state["policy"], batch,
                                            max_kl=cfg.max_kl)
            info.update(tinfo)
            new_state = {**state, "policy": new_pol,
                         "steps": state["steps"] + 1}
        else:
            new_pol, opt, loss = self._ppo_step(
                state["policy"], state["opt"], state["policy"], batch)
            info["ppo_loss"] = loss
            new_state = {**state, "policy": new_pol, "opt": opt,
                         "steps": state["steps"] + 1}
        return new_state, info

    def improve(self, state, model_params, key):
        return self._improve(state, model_params, key)


class MBMPO(_MeshMixin):
    """MB-MPO [4]: meta-policy optimization over the model ensemble.

    Inner loop: for each ensemble member m, adapt theta with one VPG step
    on imagined data from member m. Outer loop: PPO step on the
    post-adaptation surrogate averaged over members.

    On a role mesh the whole meta-step runs replicated over the policy
    sub-mesh (params placement, core/workers.py); the per-member vmap
    keeps its layout and batches are NOT constrained — constraining
    inside the member vmap would fight the vmapped axis, so
    ``_improve_impl`` simply never calls ``_shard_batch``."""

    def __init__(self, cfg: AlgoConfig, pol_cfg: PI.PolicyConfig, reward_fn,
                 init_state_fn, *, predict_fn=None, mesh=None,
                 batch_axis=None):
        self.cfg = cfg
        self.pol_cfg = pol_cfg
        self.reward_fn = reward_fn
        self.init_state_fn = init_state_fn
        self.predict_fn = predict_fn        # None = ensemble fast path
        self._outer_opt = adam(cfg.ppo_lr)
        self._improve = jax.jit(self._improve_impl)
        if mesh is not None:
            self.configure_mesh(mesh, batch_axis)

    def init(self, key):
        pol = PI.init_policy(self.pol_cfg, key)
        return {"policy": pol, "opt": self._outer_opt.init(pol),
                "steps": jnp.zeros((), jnp.int32)}

    def _member_params(self, model_params, m):
        if "members" not in model_params:
            # non-ensemble world model (predict_fn swap): every inner
            # loop adapts against the same model
            return model_params
        members = jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, m, 1, axis=0),
            model_params["members"])
        return {"members": members, "norm": model_params["norm"]}

    def _vpg_loss(self, pol, member, s0, key):
        obs, pre, rew = _rollout_with_logp(member, pol, s0, key,
                                           self.cfg.imagine_horizon,
                                           self.reward_fn, self.predict_fn)
        batch = _flat_batch(obs, pre, rew, self.cfg.gamma)
        lp = PI.log_prob(pol, batch["obs"], batch["act_pre"])
        return -(lp * batch["adv"]).mean(), rew.sum(0).mean()

    def _improve_impl(self, state, model_params, key):
        cfg = self.cfg
        pol = state["policy"]
        K = cfg.n_models

        def meta_loss(theta, key):
            def per_member(m, k):
                member = self._member_params(model_params, m)
                k_in, k_out = jax.random.split(k)
                # independent keys for start-state draws and rollout
                # sampling (reusing k_in for both correlates the inner
                # rollout's action noise with the start states)
                k_s0_in, k_roll_in = jax.random.split(k_in)
                s0 = self.init_state_fn(k_s0_in, cfg.imagine_batch)
                (l_in, _), g = jax.value_and_grad(
                    self._vpg_loss, has_aux=True)(theta, member, s0,
                                                  k_roll_in)
                adapted = jax.tree.map(lambda p, gg: p - cfg.inner_lr * gg,
                                       theta, g)
                k_s0_out, k_roll_out = jax.random.split(k_out)
                s1 = self.init_state_fn(k_s0_out, cfg.imagine_batch)
                l_out, ret = self._vpg_loss(adapted, member, s1, k_roll_out)
                return l_out, ret

            keys = jax.random.split(key, K)
            losses, rets = jax.vmap(per_member)(jnp.arange(K), keys)
            return losses.mean(), rets.mean()

        (loss, ret), g = jax.value_and_grad(meta_loss, has_aux=True)(pol, key)
        upd, opt = self._outer_opt.update(g, state["opt"], pol)
        new_pol = apply_updates(pol, upd)
        info = {"meta_loss": loss, "imagined_return": ret}
        return ({"policy": new_pol, "opt": opt,
                 "steps": state["steps"] + 1}, info)

    def improve(self, state, model_params, key):
        return self._improve(state, model_params, key)


def make_algo(cfg: AlgoConfig, pol_cfg: PI.PolicyConfig, reward_fn,
              init_state_fn, *, predict_fn=None, mesh=None,
              batch_axis=None):
    """``predict_fn=None`` -> ensemble sample-then-compute fast path;
    any ``(params, obs, act, key)`` callable swaps the world model for
    every algorithm (ME-* and MB-MPO alike). ``mesh``: policy role
    sub-mesh (core/roles.py) to shard imagination/TRPO batches over —
    usually left None and configured by the engine via
    ``algo.configure_mesh``."""
    if cfg.algo in ("me-trpo", "me-ppo"):
        return MEAlgo(cfg, pol_cfg, reward_fn, init_state_fn,
                      predict_fn=predict_fn, mesh=mesh,
                      batch_axis=batch_axis)
    if cfg.algo == "mb-mpo":
        return MBMPO(cfg, pol_cfg, reward_fn, init_state_fn,
                     predict_fn=predict_fn, mesh=mesh,
                     batch_axis=batch_axis)
    raise ValueError(cfg.algo)
