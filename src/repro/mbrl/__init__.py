from repro.mbrl.algos import AlgoConfig, MBMPO, MEAlgo, make_algo
from repro.mbrl.dynamics import EnsembleConfig
from repro.mbrl.early_stop import EMAEarlyStop
from repro.mbrl.policy import PolicyConfig
