"""Transformer world-model dynamics (the scaled path of DESIGN.md §3).

The same ``predict(params, obs, act, key)`` contract as the MLP ensemble
(`mbrl.dynamics`), but backed by a token-level decoder LM from the models/
stack: transitions are discretised with ``data.trajectory_tokens``-style
binning into sequences ``[obs tokens | act tokens | next-obs tokens]``;
training is teacher-forced next-token prediction with the loss masked to
the next-obs region; imagination decodes the next-obs tokens greedily.

Because the envs are Markov, conditioning on a single (s, a) is exact —
each imagination step is one prefill(d+a tokens) + d greedy decodes, i.e.
literally the `prefill`/`decode` serve steps the production dry-run lowers
at (32, 32768) / (128, 32768). The policy-improvement worker is agnostic:
``MEAlgo(..., predict_fn=wm.predict_fn)`` swaps the ensemble for the world
model with no other change.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm as LM
from repro.models.config import ModelConfig, ShardCtx
from repro.optim.optimizers import adam, apply_updates


@dataclasses.dataclass(frozen=True)
class WMConfig:
    obs_dim: int
    act_dim: int
    bins: int = 33
    d_model: int = 128
    num_layers: int = 2
    num_heads: int = 4
    lr: float = 1e-3


class WorldModelDynamics:
    def __init__(self, cfg: WMConfig, key):
        self.cfg = cfg
        d, a = cfg.obs_dim, cfg.act_dim
        vocab = cfg.bins * (d + a + d)   # per-position offsets, no aliasing
        self.mcfg = ModelConfig(
            name="wm", family="dense", num_layers=cfg.num_layers,
            d_model=cfg.d_model, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_heads, d_ff=cfg.d_model * 4,
            vocab_size=vocab, lr=cfg.lr)
        self.ctx = ShardCtx()            # single-device path (no shard_map)
        self.seq = 2 * d + a
        self.params = LM.init_params(self.mcfg, self.ctx, key)
        self._opt = adam(cfg.lr)
        self.opt_state = self._opt.init(self.params)
        # normalisation bounds (updated from data)
        self.norm = {"lo": jnp.full((d,), -1.0), "hi": jnp.full((d,), 1.0)}
        self._train_step = jax.jit(self._train_step_impl)
        self._predict = jax.jit(self._predict_impl)

    # ------------------------------------------------------------ tokens
    def _tok_obs(self, obs, offset_block):
        cfg = self.cfg
        lo, hi = self.norm["lo"], self.norm["hi"]
        b = jnp.clip(((obs - lo) / jnp.maximum(hi - lo, 1e-6)
                      * (cfg.bins - 1)).astype(jnp.int32), 0, cfg.bins - 1)
        off = (offset_block + jnp.arange(cfg.obs_dim)) * cfg.bins
        return b + off

    def _tok_act(self, act):
        cfg = self.cfg
        b = jnp.clip(((jnp.clip(act, -1, 1) + 1) / 2
                      * (cfg.bins - 1)).astype(jnp.int32), 0, cfg.bins - 1)
        off = (cfg.obs_dim + jnp.arange(cfg.act_dim)) * cfg.bins
        return b + off

    def _detok_obs(self, toks, offset_block):
        cfg = self.cfg
        lo, hi = self.norm["lo"], self.norm["hi"]
        off = (offset_block + jnp.arange(cfg.obs_dim)) * cfg.bins
        b = jnp.clip(toks - off, 0, cfg.bins - 1).astype(jnp.float32)
        return lo + b / (cfg.bins - 1) * (hi - lo)

    def update_normalizer(self, obs):
        self.norm = {"lo": obs.min(0) - 1e-3, "hi": obs.max(0) + 1e-3}

    # ------------------------------------------------------------- train
    def _train_step_impl(self, params, opt_state, norm, obs, act, next_obs):
        self_norm = self.norm
        object.__setattr__  # no-op: norm passed explicitly below
        d, a = self.cfg.obs_dim, self.cfg.act_dim

        def tok_batch(norm):
            lo, hi = norm["lo"], norm["hi"]
            def tobs(o, block):
                b = jnp.clip(((o - lo) / jnp.maximum(hi - lo, 1e-6)
                              * (self.cfg.bins - 1)).astype(jnp.int32),
                             0, self.cfg.bins - 1)
                off = (block + jnp.arange(d)) * self.cfg.bins
                return b + off
            tact = jnp.clip(((jnp.clip(act, -1, 1) + 1) / 2
                             * (self.cfg.bins - 1)).astype(jnp.int32),
                            0, self.cfg.bins - 1) \
                + (d + jnp.arange(a)) * self.cfg.bins
            toks = jnp.concatenate(
                [tobs(obs, 0), tact, tobs(next_obs, d + a)], axis=1)
            labels = jnp.concatenate(
                [jnp.full((obs.shape[0], d + a), -1, jnp.int32),
                 toks[:, d + a:]], axis=1)
            # next-token objective: shift labels left by one
            labels = jnp.concatenate(
                [labels[:, 1:], jnp.full((obs.shape[0], 1), -1, jnp.int32)],
                axis=1)
            return {"tokens": toks, "labels": labels}

        batch = tok_batch(norm)

        def loss_fn(p):
            s, c, aux = LM.loss_forward(self.mcfg, self.ctx, p, batch,
                                        remat=False)
            return s / jnp.maximum(c, 1)

        loss, g = jax.value_and_grad(loss_fn)(params)
        upd, opt_state = self._opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    def train_epoch(self, obs, act, next_obs, key, batch_size: int = 256):
        n = obs.shape[0]
        bs = min(batch_size, n)
        perm = jax.random.permutation(key, n)[:(n // bs) * bs].reshape(-1, bs)
        loss = 0.0
        for idx in perm:
            self.params, self.opt_state, l = self._train_step(
                self.params, self.opt_state, self.norm,
                obs[idx], act[idx], next_obs[idx])
            loss = float(l)
        return loss

    # ----------------------------------------------------------- predict
    def _predict_impl(self, params, norm, obs, act, key):
        d, a = self.cfg.obs_dim, self.cfg.act_dim
        lo, hi = norm["lo"], norm["hi"]
        B = obs.shape[0]
        ob = jnp.clip(((obs - lo) / jnp.maximum(hi - lo, 1e-6)
                       * (self.cfg.bins - 1)).astype(jnp.int32),
                      0, self.cfg.bins - 1) \
            + (jnp.arange(d) * self.cfg.bins)[None]
        ab = jnp.clip(((jnp.clip(act, -1, 1) + 1) / 2
                       * (self.cfg.bins - 1)).astype(jnp.int32),
                      0, self.cfg.bins - 1) \
            + ((d + jnp.arange(a)) * self.cfg.bins)[None]
        prompt = jnp.concatenate([ob, ab], axis=1)        # (B, d+a)
        prefill = LM.make_prefill(self.mcfg, self.ctx, B, self.seq)
        decode = LM.make_decode(self.mcfg, self.ctx, B, self.seq)
        logits, cache = prefill(params, {"tokens": prompt})
        # pad the cache out to self.seq + 1 slots
        mode_len = LM.init_cache(self.mcfg, self.ctx, B, self.seq,
                                 prefilled=False)
        pad = mode_len["k"].shape[2] - cache["k"].shape[2]
        for kk in ("k", "v"):
            cache[kk] = jnp.pad(cache[kk], ((0, 0), (0, 0), (0, pad),
                                            (0, 0), (0, 0)))
        cache["pos"] = jnp.pad(cache["pos"], (0, pad), constant_values=-1)

        outs = []
        for j in range(d):
            off = (d + a + j) * self.cfg.bins
            block = jax.lax.dynamic_slice_in_dim(logits, off, self.cfg.bins,
                                                 axis=1)
            tok_in_block = jnp.argmax(block, axis=-1)
            tok = tok_in_block + off
            outs.append(tok)
            logits, cache = decode(params, cache, tok[:, None].astype(jnp.int32))
        toks = jnp.stack(outs, axis=1)                    # (B, d)
        offs = ((d + a + jnp.arange(d)) * self.cfg.bins)[None]
        b = jnp.clip(toks - offs, 0, self.cfg.bins - 1).astype(jnp.float32)
        return lo + b / (self.cfg.bins - 1) * (hi - lo)

    def predict_fn(self):
        """predict(params, obs, act, key) with the ensemble's contract
        (shape-checked + tagged via :func:`repro.models.api.as_predict_fn`)."""
        from repro.models import api
        norm = self.norm
        return api.as_predict_fn(
            lambda params, obs, act, key: self._predict(params, norm,
                                                        obs, act, key))

    def predict(self, obs, act, key):
        return self._predict(self.params, self.norm, obs, act, key)
