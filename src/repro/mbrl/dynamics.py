"""Dynamics-model ensembles (the paper's p-hat_phi_1..K).

An ensemble of K MLPs trained on (s, a) -> delta-s with input/output
normalisation; sampling uses a uniform prior over ensemble members
(Section 3 of the paper). The batched per-member forward runs through the
``ensemble_mlp`` kernel dispatcher (Pallas grouped matmul on TPU; pure-jnp
reference elsewhere)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.gmm import ops as gmm_ops
from repro.optim.optimizers import adam, apply_updates


@dataclasses.dataclass(frozen=True)
class EnsembleConfig:
    obs_dim: int
    act_dim: int
    hidden: int = 256
    depth: int = 2
    n_models: int = 5
    lr: float = 1e-3
    train_batch: int = 256
    holdout_frac: float = 0.2


def init_member(cfg: EnsembleConfig, key):
    dims = [cfg.obs_dim + cfg.act_dim] + [cfg.hidden] * cfg.depth \
        + [cfg.obs_dim]
    ks = jax.random.split(key, len(dims) - 1)
    return {
        "w": [jax.random.normal(k, (a, b)) * (a ** -0.5)
              for k, a, b in zip(ks, dims[:-1], dims[1:])],
        "b": [jnp.zeros((b,)) for b in dims[1:]],
    }


def init_ensemble(cfg: EnsembleConfig, key):
    keys = jax.random.split(key, cfg.n_models)
    params = jax.vmap(lambda k: init_member(cfg, k))(keys)
    norm = {"mu_in": jnp.zeros(cfg.obs_dim + cfg.act_dim),
            "sig_in": jnp.ones(cfg.obs_dim + cfg.act_dim),
            "mu_out": jnp.zeros(cfg.obs_dim),
            "sig_out": jnp.ones(cfg.obs_dim)}
    return {"members": params, "norm": norm}


def update_normalizer(state, obs, act, next_obs):
    x = jnp.concatenate([obs, act], -1)
    dy = next_obs - obs
    norm = {
        "mu_in": x.mean(0), "sig_in": x.std(0) + 1e-4,
        "mu_out": dy.mean(0), "sig_out": dy.std(0) + 1e-4,
    }
    return {**state, "norm": norm}


def member_forward(member, xn):
    h = xn
    n = len(member["w"])
    for i, (w, b) in enumerate(zip(member["w"], member["b"])):
        h = h @ w + b
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def ensemble_forward(params, obs, act):
    """Per-member predictions. obs/act: (B, ·) -> (K, B, obs_dim)."""
    x = jnp.concatenate([obs, act], -1)
    n = params["norm"]
    xn = (x - n["mu_in"]) / n["sig_in"]
    dyn = gmm_ops.ensemble_mlp(params["members"], xn)
    return obs[None] + dyn * n["sig_out"] + n["mu_out"]


def predict(params, obs, act, key):
    """Uniform-prior ensemble sample: s' ~ p_phi_I, I ~ U[K] (Sec. 3)."""
    preds = ensemble_forward(params, obs, act)           # (K, B, D)
    K = preds.shape[0]
    idx = jax.random.randint(key, (obs.shape[0],), 0, K)
    return jnp.take_along_axis(
        preds, idx[None, :, None], axis=0)[0]


def mse_loss(params, obs, act, next_obs):
    n = params["norm"]
    target = (next_obs - obs - n["mu_out"]) / n["sig_out"]
    x = jnp.concatenate([obs, act], -1)
    xn = (x - n["mu_in"]) / n["sig_in"]
    pred = gmm_ops.ensemble_mlp(params["members"], xn)   # (K, B, D)
    return jnp.mean((pred - target[None]) ** 2)


def make_model_trainer(cfg: EnsembleConfig):
    opt = adam(cfg.lr)

    @jax.jit
    def train_epoch(params, opt_state, obs, act, next_obs, key):
        """One epoch of minibatch SGD over the (shuffled) buffer."""
        n = obs.shape[0]
        bs = min(cfg.train_batch, n)
        nb = max(n // bs, 1)
        perm = jax.random.permutation(key, n)[:nb * bs]
        batches = perm.reshape(nb, bs)

        def step(carry, idx):
            p, o = carry
            loss, g = jax.value_and_grad(mse_loss)(
                p, obs[idx], act[idx], next_obs[idx])
            upd, o = opt.update(g, o, p)
            return (apply_updates(p, upd), o), loss

        (params, opt_state), losses = jax.lax.scan(step, (params, opt_state),
                                                   batches)
        return params, opt_state, losses.mean()

    @jax.jit
    def val_loss(params, obs, act, next_obs):
        return mse_loss(params, obs, act, next_obs)

    return opt, train_epoch, val_loss


def imagine_rollout(params, policy_fn, policy_params, s0, key, horizon,
                    reward_fn):
    """Dyna imagination: roll the ensemble from s0 under the policy.

    s0: (B, D). Returns dict with (H, B, ·) arrays."""

    def step(carry, k):
        s = carry
        ka, kp = jax.random.split(k)
        a = policy_fn(policy_params, s, ka)
        s2 = predict(params, s, a, kp)
        r = reward_fn(s, a, s2)
        return s2, (s, a, r)

    _, (obs, act, rew) = jax.lax.scan(step, s0,
                                      jax.random.split(key, horizon))
    return {"obs": obs, "act": act, "rew": rew}
