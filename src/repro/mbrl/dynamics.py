"""Dynamics-model ensembles (the paper's p-hat_phi_1..K).

An ensemble of K MLPs trained on (s, a) -> delta-s with input/output
normalisation; sampling uses a uniform prior over ensemble members
(Section 3 of the paper).

Training evaluates every member on every row (``ensemble_mlp``: Pallas
grouped matmul on TPU; pure-jnp reference elsewhere). Imagination only
SAMPLES one member per row, so it must not PAY for all K: the hot path is
``predict_assigned`` — draw member indices up front (``sample_members``),
then per batch sort rows by member, run ONE ragged grouped MLP forward
over the (B, .) batch (B rows of FLOPs instead of K*B) and unsort
(``ensemble_mlp_select``). ``predict`` keeps the legacy
compute-all-then-select contract; under the same member assignment both
return the same next states."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.gmm import ops as gmm_ops
from repro.kernels.imag import ops as imag_ops
from repro.mbrl import policy as PI
from repro.optim.optimizers import adam, apply_updates
from repro.utils.jit_stats import trace_counted


@dataclasses.dataclass(frozen=True)
class EnsembleConfig:
    obs_dim: int
    act_dim: int
    hidden: int = 256
    depth: int = 2
    n_models: int = 5
    lr: float = 1e-3
    train_batch: int = 256
    holdout_frac: float = 0.2


def init_member(cfg: EnsembleConfig, key):
    dims = [cfg.obs_dim + cfg.act_dim] + [cfg.hidden] * cfg.depth \
        + [cfg.obs_dim]
    ks = jax.random.split(key, len(dims) - 1)
    return {
        "w": [jax.random.normal(k, (a, b)) * (a ** -0.5)
              for k, a, b in zip(ks, dims[:-1], dims[1:])],
        "b": [jnp.zeros((b,)) for b in dims[1:]],
    }


def init_ensemble(cfg: EnsembleConfig, key):
    keys = jax.random.split(key, cfg.n_models)
    params = jax.vmap(lambda k: init_member(cfg, k))(keys)
    norm = {"mu_in": jnp.zeros(cfg.obs_dim + cfg.act_dim),
            "sig_in": jnp.ones(cfg.obs_dim + cfg.act_dim),
            "mu_out": jnp.zeros(cfg.obs_dim),
            "sig_out": jnp.ones(cfg.obs_dim)}
    return {"members": params, "norm": norm}


def update_normalizer(state, obs, act, next_obs):
    return {**state,
            "norm": masked_norm_stats(obs, act, next_obs, obs.shape[0])}


def member_forward(member, xn):
    h = xn
    n = len(member["w"])
    for i, (w, b) in enumerate(zip(member["w"], member["b"])):
        h = h @ w + b
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def ensemble_forward(params, obs, act):
    """Per-member predictions. obs/act: (B, ·) -> (K, B, obs_dim)."""
    x = jnp.concatenate([obs, act], -1)
    n = params["norm"]
    xn = (x - n["mu_in"]) / n["sig_in"]
    dyn = gmm_ops.ensemble_mlp(params["members"], xn)
    return obs[None] + dyn * n["sig_out"] + n["mu_out"]


def n_members(params) -> int:
    return params["members"]["w"][0].shape[0]


def sample_members(params, key, shape):
    """Uniform prior over ensemble members (Sec. 3): I ~ U[K], iid per
    element of ``shape``. Drawn OUTSIDE the imagination scan so the whole
    horizon's assignments cost one RNG op."""
    return jax.random.randint(key, shape, 0, n_members(params))


def predict_assigned(params, obs, act, member_idx):
    """Next-state prediction with rows pre-assigned to members.

    member_idx: (B,) int in [0, K). Row b is evaluated by member
    ``member_idx[b]`` ONLY — via the sort / ragged-grouped-matmul /
    unsort path (``ensemble_mlp_select``), so a batch costs B rows of
    FLOPs, not K*B. Identical output to ``predict`` under the same
    assignment."""
    x = jnp.concatenate([obs, act], -1)
    n = params["norm"]
    xn = (x - n["mu_in"]) / n["sig_in"]
    dyn = gmm_ops.ensemble_mlp_select(params["members"], xn, member_idx)
    return obs + dyn * n["sig_out"] + n["mu_out"]


def predict(params, obs, act, key):
    """Uniform-prior ensemble sample: s' ~ p_phi_I, I ~ U[K] (Sec. 3).
    Legacy compute-all-then-select path — it PAYS for all K members on
    every call. Hot loops must not use it: imagination goes through the
    fused step (``step_fused`` / the fused ``imagine_rollout``, one
    ``kernels/imag`` dispatch per horizon step), and one-off assigned
    predictions through ``sample_members`` + ``predict_assigned``."""
    preds = ensemble_forward(params, obs, act)           # (K, B, D)
    K = preds.shape[0]
    idx = jax.random.randint(key, (obs.shape[0],), 0, K)
    return jnp.take_along_axis(
        preds, idx[None, :, None], axis=0)[0]


def masked_mse_loss(params, obs, act, next_obs, weights):
    """MSE over rows where ``weights`` is 1 — used against full-capacity
    ring storage, where rows past the valid count are garbage."""
    n = params["norm"]
    target = (next_obs - obs - n["mu_out"]) / n["sig_out"]
    x = jnp.concatenate([obs, act], -1)
    xn = (x - n["mu_in"]) / n["sig_in"]
    pred = gmm_ops.ensemble_mlp(params["members"], xn)   # (K, B, D)
    per_row = jnp.mean((pred - target[None]) ** 2, axis=(0, 2))   # (B,)
    w = weights.astype(per_row.dtype)
    return jnp.sum(per_row * w) / jnp.maximum(jnp.sum(w), 1.0)


def mse_loss(params, obs, act, next_obs):
    return masked_mse_loss(params, obs, act, next_obs,
                           jnp.ones(obs.shape[0], obs.dtype))


def _sgd_epoch_scan(opt, params, opt_state, obs, act, next_obs, batches,
                    n_active=None, shard_batch=None):
    """Scan minibatch SGD over precomputed (nb, bs) index batches —
    shared by the legacy and ring trainers.

    ``n_active`` (traced scalar, optional) limits the epoch to the first
    ``n_active`` batches WITHOUT changing the compiled shape: excess
    batches are skipped at runtime via lax.cond (one branch executes in
    an un-vmapped scan), so a ring trainer's static grid does
    epoch-proportional work on a partially filled buffer and full grid
    work only at steady state.

    ``shard_batch`` (optional, x -> x): sharding constraint applied to
    each gathered minibatch — the data-parallel hook for role sub-meshes
    (params replicated, per-device grads, XLA inserts the psum)."""

    def sgd(p, o, idx):
        mb = (obs[idx], act[idx], next_obs[idx])
        if shard_batch is not None:
            mb = tuple(shard_batch(x) for x in mb)
        loss, g = jax.value_and_grad(mse_loss)(p, *mb)
        upd, o = opt.update(g, o, p)
        return apply_updates(p, upd), o, loss

    def step(carry, xs):
        i, idx = xs
        p, o = carry
        if n_active is None:
            p2, o2, loss = sgd(p, o, idx)
            return (p2, o2), loss
        p2, o2, loss = jax.lax.cond(
            i < n_active, sgd,
            lambda p, o, idx: (p, o, jnp.zeros((), obs.dtype)), p, o, idx)
        return (p2, o2), loss

    nb = batches.shape[0]
    (params, opt_state), losses = jax.lax.scan(
        step, (params, opt_state), (jnp.arange(nb), batches))
    if n_active is None:
        return params, opt_state, losses.mean()
    return params, opt_state, losses.sum() / jnp.maximum(n_active, 1)


def make_model_trainer(cfg: EnsembleConfig):
    """Legacy dynamic-shape trainer (retraces when the data size changes;
    prefer make_ring_trainer on the hot path)."""
    opt = adam(cfg.lr)

    @jax.jit
    def train_epoch(params, opt_state, obs, act, next_obs, key):
        """One epoch of minibatch SGD over the (shuffled) buffer."""
        n = obs.shape[0]
        bs = min(cfg.train_batch, n)
        nb = max(n // bs, 1)
        perm = jax.random.permutation(key, n)[:nb * bs]
        return _sgd_epoch_scan(opt, params, opt_state, obs, act, next_obs,
                               perm.reshape(nb, bs))

    @jax.jit
    def val_loss(params, obs, act, next_obs):
        return mse_loss(params, obs, act, next_obs)

    return opt, train_epoch, val_loss


def masked_norm_stats(obs, act, next_obs, size):
    """Normalizer stats against ring storage: moments over the first
    ``size`` valid rows (``size`` is traced — shapes stay static).
    Returns only the ``norm`` dict so a jitted caller never copies the
    ensemble members."""
    w = (jnp.arange(obs.shape[0]) < size).astype(obs.dtype)
    tot = jnp.maximum(w.sum(), 1.0)

    def moments(v):
        mu = (v * w[:, None]).sum(0) / tot
        var = (((v - mu) ** 2) * w[:, None]).sum(0) / tot
        return mu, jnp.sqrt(var) + 1e-4

    x = jnp.concatenate([obs, act], -1)
    dy = next_obs - obs
    mu_in, sig_in = moments(x)
    mu_out, sig_out = moments(dy)
    return {"mu_in": mu_in, "sig_in": sig_in,
            "mu_out": mu_out, "sig_out": sig_out}


def make_ring_trainer(cfg: EnsembleConfig, capacity: int,
                      *, epoch_batches: int | None = None,
                      max_epoch_batches: int = 64,
                      batch_sharding=None):
    """Retrace-free trainer over fixed-capacity ring storage.

    All three returned functions close over STATIC shapes only
    (``capacity`` and the static minibatch grid), so each compiles exactly
    once regardless of how full the buffer is:

    * ``update_norm(data, size)`` — masked normalizer stats (returns the
      ``norm`` dict only, so no ensemble-member copy per refresh).
    * ``train_epoch(params, opt_state, data, size, key)`` — a fixed grid
      of ``nb`` minibatches of ``cfg.train_batch`` indices sampled
      uniformly (with replacement) from the valid region ``[0, size)``;
      only the first ``clip(size // bs, 1, nb)`` batches apply their
      updates, so one epoch is one pass over the CURRENT data (like the
      legacy trainer) while the compiled shape never changes.
      ``params``/``opt_state`` are donated so the optimizer updates in
      place where the backend supports buffer aliasing.
    * ``val_loss(params, data, size)`` — masked MSE over a val ring.

    ``train_epoch`` and ``val_loss`` carry a ``.trace_count`` attribute
    (see repro.utils.jit_stats) so benchmarks/tests can assert the
    no-retrace invariant.

    ``batch_sharding`` (role meshes): a ``NamedSharding`` over the owning
    sub-mesh's batch axis. Ring storage arrives pre-sharded from
    :class:`repro.core.servers.ReplayBuffer`; each gathered minibatch is
    constrained to the same sharding so the SGD step runs data-parallel
    (params replicated, per-device grads psum'd by XLA). Same math, same
    compile-once guarantee.
    """
    opt = adam(cfg.lr)
    bs = min(cfg.train_batch, max(int(capacity), 1))
    nb = epoch_batches if epoch_batches is not None else \
        min(max(int(capacity) // bs, 1), max_epoch_batches)
    shard_batch = None
    if batch_sharding is not None:
        shard_batch = lambda x: jax.lax.with_sharding_constraint(
            x, batch_sharding)

    def _train_epoch(params, opt_state, data, size, key):
        idx = jax.random.randint(key, (nb, bs), 0,
                                 jnp.maximum(size, 1))
        # one pass over the VALID region per epoch (like the legacy
        # trainer), not over the whole capacity grid
        n_active = jnp.clip(size // bs, 1, nb)
        return _sgd_epoch_scan(opt, params, opt_state, data["obs"],
                               data["act"], data["next_obs"], idx,
                               n_active=n_active, shard_batch=shard_batch)

    def _val_loss(params, data, size):
        w = jnp.arange(data["obs"].shape[0]) < size
        return masked_mse_loss(params, data["obs"], data["act"],
                               data["next_obs"], w)

    def _update_norm(data, size):
        return masked_norm_stats(data["obs"], data["act"],
                                 data["next_obs"], size)

    train_epoch = trace_counted(_train_epoch, donate_argnums=(0, 1))
    val_loss = trace_counted(_val_loss)
    update_norm = trace_counted(_update_norm)
    return opt, train_epoch, val_loss, update_norm


def step_fused(params, policy_params, s, eps, member_idx, *, impl=None,
               interpret=False, plan=None):
    """One FUSED imagination step: policy head + reparameterised action
    + assigned-member dynamics forward as a single ``kernels/imag``
    dispatch (Pallas megakernel on TPU, one flat XLA body elsewhere).

    s: (B, obs); eps: (B, act) standard normal (pre-drawn — hoist the
    whole horizon's draws out of the scan); member_idx: (B,) int.
    ``plan``: precomputed ``imag_ops.sort_plan`` slice for this step's
    assignment (pallas impl; keeps the sort/unsort out of the scan body).
    Returns ``(s2, a, pre)``."""
    return imag_ops.fused_step(params["members"], params["norm"],
                               policy_params, s, eps, member_idx,
                               impl=impl, interpret=interpret, plan=plan)


def horizon_plan(params, member_idx):
    """Sort/unsort plans for a whole horizon of member assignments
    ((H, B) int), for threading through a rollout scan — or None when the
    backend's fused impl doesn't sort (the flat XLA path is
    row-order-blind, so no plan is ever computed on CPU/GPU)."""
    if imag_ops.default_impl() != "pallas":
        return None
    return imag_ops.sort_plan(member_idx, n_members(params))


def hoisted_noise(key, horizon, batch, act_dim):
    """The whole horizon's policy noise in one op, bit-identical to the
    per-step ``normal(keys[h], (B, act))`` draws of the legacy scan."""
    return jax.vmap(lambda k: jax.random.normal(k, (batch, act_dim)))(
        jax.random.split(key, horizon))


def imagine_rollout(params, policy_fn, policy_params, s0, key, horizon,
                    reward_fn, *, fused=None):
    """Dyna imagination: roll the ensemble from s0 under the policy.

    s0: (B, D). Returns dict with (H, B, ·) arrays. Sample-then-compute:
    the whole horizon's member assignments AND policy noise are drawn up
    front, and each step is ONE fused ``step_fused`` dispatch (policy
    head + assigned-member dynamics, no K* ensemble overcompute and no
    per-step sort inside the scan).

    ``fused=None`` auto-detects: the fused path replicates exactly the
    tanh-Gaussian ``PI.sample_action``, so any other ``policy_fn`` (or
    ``fused=False``) takes the legacy per-step path
    (``policy_fn`` + ``predict_assigned``) instead."""
    if fused is None:
        fused = policy_fn is PI.sample_action
    ka, kp = jax.random.split(key)
    members = sample_members(params, kp, (horizon, s0.shape[0]))
    keys = jax.random.split(ka, horizon)

    if not fused:
        def step(carry, xs):
            k, midx = xs
            s = carry
            a = policy_fn(policy_params, s, k)
            s2 = predict_assigned(params, s, a, midx)
            r = reward_fn(s, a, s2)
            return s2, (s, a, r)

        _, (obs, act, rew) = jax.lax.scan(step, s0, (keys, members))
        return {"obs": obs, "act": act, "rew": rew}

    act_dim = policy_params["w"][-1].shape[1]
    eps = hoisted_noise(ka, horizon, s0.shape[0], act_dim)
    plan = horizon_plan(params, members)

    def step(carry, xs):
        e, midx, pl_ = xs
        s = carry
        s2, a, _pre = step_fused(params, policy_params, s, e, midx,
                                 plan=pl_)
        r = reward_fn(s, a, s2)
        return s2, (s, a, r)

    _, (obs, act, rew) = jax.lax.scan(step, s0, (eps, members, plan))
    return {"obs": obs, "act": act, "rew": rew}
