"""PPO (clipped surrogate) [27] — used by ME-PPO and as the model-free
baseline; one jitted gradient step so the async policy worker's Step is
the paper's minimal unit of work."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mbrl import policy as PI
from repro.optim.optimizers import adam, apply_updates


def ppo_loss(params, params_old, batch, *, clip=0.2, ent_coef=0.0):
    lp = PI.log_prob(params, batch["obs"], batch["act_pre"])
    lp_old = PI.log_prob(params_old, batch["obs"], batch["act_pre"])
    ratio = jnp.exp(lp - lp_old)
    adv = batch["adv"]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - clip, 1 + clip) * adv
    pg = -jnp.minimum(unclipped, clipped).mean()
    return pg - ent_coef * PI.entropy(params)


def make_ppo_step(lr=3e-4, clip=0.2, ent_coef=0.0):
    opt = adam(lr)

    @jax.jit
    def step(params, opt_state, params_old, batch):
        loss, g = jax.value_and_grad(ppo_loss)(params, params_old, batch,
                                               clip=clip, ent_coef=ent_coef)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    return opt, step
