"""Tanh-squashed Gaussian MLP policy (paper's pi_theta)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    obs_dim: int
    act_dim: int
    hidden: int = 64
    depth: int = 2
    init_log_std: float = -0.5


def init_policy(cfg: PolicyConfig, key):
    dims = [cfg.obs_dim] + [cfg.hidden] * cfg.depth + [cfg.act_dim]
    ks = jax.random.split(key, len(dims) - 1)
    return {
        "w": [jax.random.normal(k, (a, b)) * (a ** -0.5)
              for k, a, b in zip(ks, dims[:-1], dims[1:])],
        "b": [jnp.zeros((b,)) for b in dims[1:]],
        # strong f32 dtype: a weak-typed leaf here flips to strong after
        # the first gradient step, forcing every consumer jit to retrace
        "log_std": jnp.full((cfg.act_dim,), cfg.init_log_std, jnp.float32),
    }


def mean_action(params, obs):
    h = obs
    n = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        h = h @ w + b
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def _noise_shape(params, obs):
    return obs.shape[:-1] + (params["w"][-1].shape[1],)


def sample_from_eps(params, obs, eps):
    """Reparameterised sample from PRE-DRAWN standard-normal noise:
    ``pre = mu + exp(log_std) * eps``, returns ``(tanh(pre), pre)``.

    The single source of the sampling arithmetic — ``sample_action`` /
    ``sample_with_logp`` draw ``eps`` and delegate here, and the fused
    imagination step (``kernels/imag``) reproduces exactly this with the
    whole horizon's ``eps`` hoisted out of the scan."""
    mu = mean_action(params, obs)
    pre = mu + jnp.exp(params["log_std"]) * eps
    return jnp.tanh(pre), pre


def sample_action(params, obs, key):
    eps = jax.random.normal(key, _noise_shape(params, obs))
    return sample_from_eps(params, obs, eps)[0]


def sample_action_scaled(params, obs, key, noise_scale):
    """Exploration-scaled sampling for heterogeneous collector fleets:
    the policy's Gaussian std is multiplied by ``noise_scale`` before
    the draw (scale 1.0 reproduces :func:`sample_action` exactly — the
    same key draws the same noise)."""
    mu = mean_action(params, obs)
    std = jnp.exp(params["log_std"]) * noise_scale
    return jnp.tanh(mu + std * jax.random.normal(key, mu.shape))


def deterministic_action(params, obs, key=None):
    return jnp.tanh(mean_action(params, obs))


def log_prob(params, obs, act_pre_tanh):
    """Gaussian log-prob of the PRE-tanh action (we store pre-tanh acts
    during collection for exact densities)."""
    mu = mean_action(params, obs)
    log_std = params["log_std"]
    z = (act_pre_tanh - mu) / jnp.exp(log_std)
    return (-0.5 * z ** 2 - log_std - 0.5 * jnp.log(2 * jnp.pi)).sum(-1)


def sample_with_logp(params, obs, key):
    eps = jax.random.normal(key, _noise_shape(params, obs))
    a, pre = sample_from_eps(params, obs, eps)
    return a, pre, log_prob(params, obs, pre)


def kl_divergence(params_old, params_new, obs):
    """KL(old || new) of the Gaussians (pre-tanh), averaged over obs."""
    mu0 = mean_action(params_old, obs)
    mu1 = mean_action(params_new, obs)
    ls0, ls1 = params_old["log_std"], params_new["log_std"]
    v0, v1 = jnp.exp(2 * ls0), jnp.exp(2 * ls1)
    kl = (ls1 - ls0 + (v0 + (mu0 - mu1) ** 2) / (2 * v1) - 0.5).sum(-1)
    return kl.mean()


def entropy(params):
    return (params["log_std"] + 0.5 * jnp.log(2 * jnp.pi * jnp.e)).sum()
