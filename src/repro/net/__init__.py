"""Socket transport for the control plane (PR 9).

The multi-host seam behind ``core/servers.py``: the same versioned
parameter stores and exact-criterion data server, reachable over TCP.
See docs/WIRE_PROTOCOL.md for the frame format and
docs/ARCHITECTURE.md for where this sits in the system.

* :mod:`repro.net.frame` — the 32-byte frame header (version word
  rides the header, so unchanged pulls move zero array bytes) and the
  LeafCodec / tree-frame payload encodings;
* :mod:`repro.net.control` — :class:`ControlPlane`, the threaded
  server hosting every store of a run behind one ``--bind`` address;
* :mod:`repro.net.client` — :class:`TcpParameterServer` /
  :class:`TcpDataServer`, drop-in peers of the shm/mp servers;
* :mod:`repro.net.join` — ``--connect``: join a live run as extra
  remote collectors.
"""
from repro.net.client import TcpDataServer, TcpParameterServer
from repro.net.control import ControlPlane, parse_addr
from repro.net.frame import ProtocolError
from repro.net.join import join_as_collectors

__all__ = ["ControlPlane", "TcpParameterServer", "TcpDataServer",
           "ProtocolError", "parse_addr", "join_as_collectors"]
