"""Socket-transport clients: drop-in peers of the shm/mp servers.

``TcpParameterServer`` and ``TcpDataServer`` expose the exact method
surface of ``ShmParameterServer`` / ``ProcDataServer`` (pull_if_newer,
try_claim/refund_inflight, push/push_batch/drain, the counters the
benchmarks and the InvariantMonitor read), so ``ProcChannels``, the
worker loops, and the supervision code are transport-blind.

Each handle owns ONE lazily-dialled TCP connection guarded by a thread
lock; handles pickle across spawn (socket and lock are dropped and
re-created), so they ride ``ProcSpec``/``ProcChannels`` into children
exactly like the shm handles do. A connection error closes the socket
and the next call redials — a reconnecting collector resumes the
GLOBAL counters because all state lives on the plane.
"""
from __future__ import annotations

import pickle
import socket
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.net import frame as F


class _TcpHandle:
    """One RPC connection: lazy dial, serialised request/reply, redial
    after any failure. Picklable (socket/lock dropped)."""

    def __init__(self, addr: Tuple[str, int], *, timeout: float = 60.0):
        self._addr = tuple(addr)
        self._timeout = float(timeout)
        self._sock = None
        self._lock = threading.Lock()

    def __getstate__(self):
        state = dict(self.__dict__)
        state["_sock"] = None
        state["_lock"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()

    def _conn(self):
        if self._sock is None:
            s = socket.create_connection(self._addr, timeout=self._timeout)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock = s
        return self._sock

    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _rpc(self, op: int, *, word: int = 0, aux: int = 0, flags: int = 0,
             payload: bytes = b"") -> Tuple[int, int, int, int, bytes]:
        """Send one frame, read one reply. On ANY transport failure the
        socket is dropped (next call redials) and the error propagates —
        callers choose whether to degrade (gated pulls) or stay loud
        (pushes, claims)."""
        with self._lock:
            try:
                sock = self._conn()
                F.send_frame(sock, op, word=word, aux=aux, flags=flags,
                             payload=payload)
                rop, rword, raux, rflags, rpayload = F.recv_frame(sock)
            except (F.ProtocolError, OSError):
                self._drop()
                raise
        if rop == F.OP_ERR:
            raise RuntimeError("control plane error: "
                               + rpayload.decode(errors="replace"))
        return rop, rword, raux, rflags, rpayload

    def close(self) -> None:
        with self._lock:
            self._drop()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TcpParameterServer(_TcpHandle):
    """Versioned parameter store over the socket transport.

    The version word rides the FRAME HEADER: an unchanged
    ``pull_if_newer`` is one 32-byte request + one 32-byte reply with
    zero payload bytes — ``array_bytes_received`` (every parameter
    payload byte this handle ever read) stays untouched, the
    counter-asserted mirror of the shm path's zero-copy contract. A
    transport failure during a gated pull DEGRADES to the cached value
    ((None, version), socket redialled next call) exactly like a seqlock
    reader seeing a crashed writer; pushes stay loud.
    """

    def __init__(self, addr, store_id: int, name: str = "",
                 template=None, *, timeout: float = 60.0):
        super().__init__(addr, timeout=timeout)
        self.store_id = int(store_id)
        self.name = name
        self._codec = None
        if template is not None:
            from repro.checkpoint.io import LeafCodec
            self._codec = LeafCodec(template)
        self.copies = 0                 # leaves copied out (parity w/ shm)
        self.pushes = 0
        self.array_bytes_received = 0   # parameter payload bytes pulled

    def _ensure_codec(self, value=None):
        if self._codec is None:
            if value is not None:
                from repro.checkpoint.io import LeafCodec
                self._codec = LeafCodec(value)
                # publish for template-less peers (remote joiners)
                self._rpc(F.OP_PINIT, aux=self.store_id,
                          payload=pickle.dumps(self._codec))
            else:
                _, _, _, _, blob = self._rpc(F.OP_PMETA, aux=self.store_id)
                self._codec = pickle.loads(blob)
        return self._codec

    def push(self, value) -> int:
        """Encode leaves with the shared LeafCodec, swap the server blob,
        bump the version word. Loud on failure (a lost push must never
        pass silently). Returns the new version."""
        codec = self._ensure_codec(value)
        _, ver, _, _, _ = self._rpc(F.OP_PPUSH, aux=self.store_id,
                                    payload=F.encode_leaves(codec, value))
        self.pushes += 1
        return ver

    def pull_if_newer(self, version: int, *, sharding=None):
        """(value, current_version) when newer than ``version``, else
        (None, version-as-seen). Unchanged cost: one header-only
        round-trip, zero array bytes. Transport failure: degrade to
        (None, version) — the caller keeps its cache. ``sharding`` is
        accepted for interface parity and ignored (pulled leaves are
        host arrays; each process re-homes them onto its own backend)."""
        try:
            _, ver, _, _, payload = self._rpc(F.OP_PPULL, word=version,
                                              aux=self.store_id)
            if not payload:
                return None, ver
            value = F.decode_leaves(self._ensure_codec(), payload)
        except (F.ProtocolError, OSError):
            return None, version
        self.array_bytes_received += len(payload)
        self.copies += self._codec.n_leaves
        return value, ver

    def pull(self):
        """Unconditional pull -> (value-or-None, version)."""
        value, ver = self.pull_if_newer(-1)
        return value, (ver if value is not None else self.version)

    def pull_host(self):
        """Interface parity with ParameterServer: pulls are already
        host-materialised."""
        return self.pull()

    @property
    def version(self) -> int:
        """Current server version: one header-only RPC (loud on
        failure — monitors poll this only while the plane is up)."""
        _, ver, _, _, _ = self._rpc(F.OP_PVER, aux=self.store_id)
        return ver


class TcpDataServer(_TcpHandle):
    """The trajectory data server over the socket transport.

    Exact-criterion ticket protocol as explicit RPCs with the shm/mp
    semantics verbatim: ``try_claim(collector_id, k)`` grants
    ``min(k, remaining)`` under the plane's one lock (denied claims
    back off ``claim_backoff`` client-side), ``refund_inflight``
    returns EXACTLY the stranded count of a collector that died between
    claim and push, a push that times out on a full queue raises
    :class:`repro.core.servers.BackpressureError` with the same
    diagnosis. All counters live on the plane, so a SIGKILLed-and-
    replaced collector resumes the GLOBAL count.
    """

    def __init__(self, addr, *, n_collectors: int = 1,
                 push_timeout: float = 30.0, claim_backoff: float = 0.002,
                 timeout: float = 60.0):
        # rpc timeout must exceed the server-side full-queue wait
        super().__init__(addr, timeout=max(timeout, push_timeout + 30.0))
        self.n_collectors = max(int(n_collectors), 1)
        self.push_timeout = float(push_timeout)
        self.claim_backoff = float(claim_backoff)

    def _raise_backpressure(self, collector_id, timeout, maxsize):
        from repro.core.servers import BackpressureError
        raise BackpressureError(
            f"trajectory queue full: collector {collector_id} waited "
            f"{timeout:.1f}s to push and the queue still holds "
            f"{maxsize} (maxsize) undrained items. The slowest "
            "consumer is the model worker's drain->ring-write path "
            "(ModelLearningWorker._refresh_data); raise "
            "RunConfig.push_timeout_s, enlarge the queue, or check "
            "whether the model process is wedged/compiling."
        ) from None

    def _push_blob(self, blob: bytes, n: int, collector_id: int,
                   timeout: Optional[float]) -> int:
        timeout = self.push_timeout if timeout is None else timeout
        op, total, _, _, _ = self._rpc(
            F.OP_DPUSH, word=int(timeout * 1000), aux=int(collector_id),
            flags=int(n), payload=blob)
        if op == F.OP_FULL:
            self._raise_backpressure(collector_id, timeout, total or 512)
        return total

    def push(self, traj, *, collector_id: int = 0,
             timeout: Optional[float] = None) -> int:
        """Host-materialise one trajectory, ship it as a self-describing
        tree frame, settle one in-flight ticket atomically server-side.
        Full queue after ``timeout``: BackpressureError (loud)."""
        host = jax.tree.map(np.asarray, traj)
        return self._push_blob(F.encode_tree(host), 1, collector_id,
                               timeout)

    def push_batch(self, batch, n: int, *, collector_id: int = 0,
                   timeout: Optional[float] = None) -> int:
        """Ship ``n`` stacked trajectories as ONE queue item (one frame,
        one ticket settlement of n) — drain unstacks lanes consumer-side
        exactly like ``ProcDataServer``."""
        host = jax.tree.map(np.asarray, batch)
        return self._push_blob(F.encode_tree(host), int(n), collector_id,
                               timeout)

    def try_claim(self, collector_id: int = 0, k: int = 1) -> int:
        """Reserve up to ``k`` slots toward the global target (one RPC,
        granted = min(k, remaining) under the plane lock); 0 once the
        target is fully claimed. Denied claims sleep ``claim_backoff``
        client-side so remote losers of the final-claim race back off
        without holding a connection thread."""
        _, g, _, _, _ = self._rpc(F.OP_DCLAIM, word=int(k),
                                  aux=int(collector_id))
        if g == 0:
            time.sleep(self.claim_backoff)
        return g

    def refund_inflight(self, collector_id: int) -> int:
        """Return EXACTLY the tickets ``collector_id`` claimed but never
        pushed (it died mid-batch); idempotent — a second refund is 0."""
        _, g, _, _, _ = self._rpc(F.OP_DREFUND, aux=int(collector_id))
        return g

    def drain(self) -> List[Any]:
        """Move everything queued to the caller as per-trajectory dicts;
        batch items are unstacked into np views along the lane axis."""
        _, count, _, _, payload = self._rpc(F.OP_DDRAIN)
        out: List[Any] = []
        for n, blob in F.unpack_drain_items(payload, count):
            tree = F.decode_tree(blob)
            if n > 1:
                out.extend({k: v[i] for k, v in tree.items()}
                           for i in range(n))
            else:
                out.append(tree)
        return out

    def set_target(self, total: int) -> None:
        """Arm the stopping criterion: from now on claims grant exactly
        ``total - total_pushed`` more slots."""
        self._rpc(F.OP_DTARGET, word=int(total))

    @property
    def total_pushed(self) -> int:
        """Exact global trajectory count (one RPC; plane-side lock)."""
        _, total, _, _, _ = self._rpc(F.OP_DTOTAL)
        return total

    def __len__(self) -> int:
        _, n, _, _, _ = self._rpc(F.OP_DLEN)
        return n
