"""Wire format of the socket control plane (see docs/WIRE_PROTOCOL.md).

One frame = a fixed 32-byte little-endian header + an opaque payload:

    magic  4s   b"RPN1"  (repro net, version 1)
    op     u16  opcode (request or reply)
    flags  u16  op-specific small integer (e.g. batch lane count)
    word   i64  THE version/result word: a parameter pull carries the
                client's version out and the server's version back in
                this header field, so an unchanged pull is one 32-byte
                request + one 32-byte reply with ZERO payload bytes
    aux    i64  op-specific integer (store id / collector id / timeout)
    len    u64  payload byte count

Integrity is TCP's: a torn write surfaces as a short read or a bad
magic, both raised as :class:`ProtocolError` — readers degrade to their
cached value (mirroring the shm seqlock's crashed-writer path), they
never decode a torn frame.

Two payload encodings ride the frames:

* fixed-structure parameter payloads: the leaves of
  ``checkpoint/io.LeafCodec`` concatenated in codec order, storable
  dtypes, no padding (``encode_leaves``/``decode_leaves``) — both ends
  hold the same codec, so no per-frame metadata is needed;
* self-describing trajectory payloads ("tree frames"): a u32-length
  JSON header (keys/dtypes/shapes) + concatenated C-order buffers
  (``encode_tree``/``decode_tree``) — trajectory dicts are not known to
  the server at construction time.
"""
from __future__ import annotations

import json
import struct
from typing import Dict, List, Tuple

import ml_dtypes
import numpy as np

from repro.checkpoint.io import _to_storable

MAGIC = b"RPN1"
_HDR = struct.Struct("<4sHHqqQ")
HEADER_SIZE = _HDR.size            # 32

# ---- opcodes ---------------------------------------------------------
# parameter stores (aux = store id)
OP_PPUSH = 1        # payload=leaf bytes            -> OK word=new version
OP_PPULL = 2        # word=client version           -> OK word=version,
                    #   payload empty (unchanged) or leaf bytes (changed)
OP_PVER = 3         #                               -> OK word=version
OP_PMETA = 4        #                               -> OK payload=codec blob
OP_PINIT = 5        # payload=codec blob (idempotent) -> OK
# the data server (aux = collector id)
OP_DPUSH = 10       # flags=n lanes, word=timeout ms, payload=tree frame
                    #   -> OK word=total | FULL word=maxsize
OP_DCLAIM = 12      # word=k                        -> OK word=granted
OP_DREFUND = 13     #                               -> OK word=refunded
OP_DDRAIN = 14      #          -> OK word=item count, payload=item list
OP_DTOTAL = 15      #                               -> OK word=total
OP_DTARGET = 16     # word=target                   -> OK
OP_DLEN = 17        #                               -> OK word=pending items
# control
OP_JOIN = 20        #                -> OK payload=pickled join ticket
# replies
OP_OK = 100
OP_ERR = 101        # payload=utf-8 message (re-raised client-side)
OP_FULL = 102       # data push timed out on a full queue


class ProtocolError(RuntimeError):
    """A frame failed to parse: short read, bad magic, or truncated
    payload. The connection is unusable and must be closed; client pulls
    degrade to their cache exactly like a seqlock reader seeing a
    crashed writer."""


def pack_frame(op: int, *, word: int = 0, aux: int = 0, flags: int = 0,
               payload: bytes = b"") -> bytes:
    return _HDR.pack(MAGIC, op, flags, word, aux, len(payload)) + payload


def send_frame(sock, op: int, *, word: int = 0, aux: int = 0,
               flags: int = 0, payload: bytes = b"") -> None:
    sock.sendall(pack_frame(op, word=word, aux=aux, flags=flags,
                            payload=payload))


def recv_exact(sock, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise :class:`ProtocolError` — a peer
    dying mid-frame can only ever produce a short read here, never a
    partially-decoded frame."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise ProtocolError(
                f"connection closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock) -> Tuple[int, int, int, int, bytes]:
    """-> (op, word, aux, flags, payload). Raises ProtocolError on a
    short read or bad magic; never returns a torn frame."""
    hdr = recv_exact(sock, HEADER_SIZE)
    magic, op, flags, word, aux, plen = _HDR.unpack(hdr)
    if magic != MAGIC:
        raise ProtocolError(f"bad frame magic {magic!r}")
    payload = recv_exact(sock, plen) if plen else b""
    return op, word, aux, flags, payload


# ---- fixed-structure parameter payloads (LeafCodec both ends) --------
def encode_leaves(codec, tree) -> bytes:
    """Pytree -> one contiguous byte string: the codec's storable leaves
    concatenated in codec order (sizes are fixed by the codec, so the
    receiver needs no per-frame metadata)."""
    return b"".join(a.tobytes() for a in codec.encode(tree))


def decode_leaves(codec, payload: bytes):
    """Inverse of :func:`encode_leaves` -> pytree with original dtypes.
    Raises ProtocolError if the payload length does not match the codec
    (a torn or foreign frame must never decode)."""
    expect = sum(codec.nbytes)
    if len(payload) != expect:
        raise ProtocolError(
            f"parameter payload is {len(payload)} bytes, codec needs "
            f"{expect}")
    out, off = [], 0
    for sd, sh, n in zip(codec.storable_dtypes, codec.shapes, codec.nbytes):
        count = int(np.prod(sh, dtype=np.int64))
        out.append(np.frombuffer(payload, dtype=sd, count=count,
                                 offset=off).reshape(sh))
        off += int(n)
    return codec.decode(out)


# ---- self-describing trajectory payloads ("tree frames") -------------
def _dtype_by_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def encode_tree(d: Dict[str, np.ndarray]) -> bytes:
    """Flat dict of arrays -> u32 JSON-header length + JSON
    (keys/dtypes/shapes) + concatenated C-order storable buffers.
    Exotic dtypes (bf16, fp8) ride as same-width uint views, exactly
    like checkpoints."""
    keys = list(d.keys())
    arrs = [np.ascontiguousarray(_to_storable(np.asarray(d[k])))
            for k in keys]
    meta = json.dumps({
        "keys": keys,
        "dtypes": [np.dtype(getattr(np.asarray(d[k]), "dtype")).name
                   for k in keys],
        "sdtypes": [a.dtype.str for a in arrs],
        "shapes": [list(a.shape) for a in arrs],
    }).encode()
    return struct.pack("<I", len(meta)) + meta \
        + b"".join(a.tobytes() for a in arrs)


def decode_tree(payload: bytes) -> Dict[str, np.ndarray]:
    """Inverse of :func:`encode_tree`. Raises ProtocolError on any
    truncation or metadata/buffer length mismatch."""
    from repro.checkpoint.io import _from_storable
    if len(payload) < 4:
        raise ProtocolError("tree frame shorter than its length prefix")
    (jlen,) = struct.unpack_from("<I", payload, 0)
    if len(payload) < 4 + jlen:
        raise ProtocolError("tree frame truncated inside JSON header")
    try:
        meta = json.loads(payload[4:4 + jlen])
        keys = meta["keys"]
        dtypes = meta["dtypes"]
        sdtypes = [np.dtype(s) for s in meta["sdtypes"]]
        shapes = [tuple(s) for s in meta["shapes"]]
    except (ValueError, KeyError, TypeError) as e:
        raise ProtocolError(f"garbled tree-frame header: {e}") from None
    off = 4 + jlen
    out: Dict[str, np.ndarray] = {}
    for k, dt, sd, sh in zip(keys, dtypes, sdtypes, shapes):
        count = int(np.prod(sh, dtype=np.int64))
        need = count * sd.itemsize
        if len(payload) < off + need:
            raise ProtocolError(f"tree frame truncated in leaf {k!r}")
        arr = np.frombuffer(payload, dtype=sd, count=count,
                            offset=off).reshape(sh)
        out[k] = _from_storable(arr, _dtype_by_name(dt))
        off += need
    return out


def pack_drain_items(items: List[Tuple[int, bytes]]) -> bytes:
    """Drain reply payload: per queued item, u32 lane count + u32 byte
    length + the item's tree frame."""
    parts = []
    for n, blob in items:
        parts.append(struct.pack("<II", n, len(blob)))
        parts.append(blob)
    return b"".join(parts)


def unpack_drain_items(payload: bytes, count: int) -> List[Tuple[int, bytes]]:
    items, off = [], 0
    for _ in range(count):
        if len(payload) < off + 8:
            raise ProtocolError("drain reply truncated in item header")
        n, blen = struct.unpack_from("<II", payload, off)
        off += 8
        if len(payload) < off + blen:
            raise ProtocolError("drain reply truncated in item body")
        items.append((n, payload[off:off + blen]))
        off += blen
    return items
