"""ControlPlane: the socket-transport server side (stdlib only).

One plane hosts the whole control surface of a run — every parameter
store (model, policy) and THE data server — behind one TCP listener, so
`--bind host:port` publishes a single address that collectors anywhere
can reach. Server state is plain threaded-Python mirrors of the shm/mp
structures in ``core/servers.py``:

* each parameter store is (lock, payload bytes, version int): a push
  swaps the payload and bumps the version under the lock, a pull
  compares the client's version word first — unchanged replies carry
  ZERO payload bytes;
* the data plane is (condition, bounded deque of encoded items, the
  ticket counters): ``total`` / ``tickets`` / per-collector in-flight
  counts move under ONE lock, so the exact-criterion contract of
  ``ProcDataServer`` — claims stop at the target, a crashed collector's
  stranded tickets come back in one refund — holds verbatim over TCP.

Crash semantics: a client SIGKILLed mid-frame just drops its
connection; the handler thread exits and server state is untouched —
tickets stay in flight until someone calls ``refund_inflight`` for that
collector, exactly like the shm path's supervising parent. The plane
never auto-refunds on disconnect (a live collector reconnecting after
a network blip must NOT have its tickets yanked).
"""
from __future__ import annotations

import pickle
import socket
import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.net import frame as F


def parse_addr(addr: str) -> Tuple[str, int]:
    """'host:port' -> (host, port). Accepts ':port' as all-interfaces."""
    host, _, port = addr.rpartition(":")
    return (host or "0.0.0.0", int(port))


class _ParamStore:
    """Server-side versioned blob: payload bytes + version word under
    one lock. The server never decodes parameters — it moves bytes."""

    def __init__(self, codec_blob: Optional[bytes] = None):
        self.lock = threading.Lock()
        self.payload = b""
        self.version = 0
        self.codec_blob = codec_blob

    def push(self, payload: bytes) -> int:
        with self.lock:
            self.payload = payload
            self.version += 1
            return self.version

    def pull(self, version: int) -> Tuple[Optional[bytes], int]:
        with self.lock:
            if self.version == version or self.version == 0:
                return None, self.version
            return self.payload, self.version


class _DataPlane:
    """Server-side trajectory queue + the exact-criterion ticket
    counters, all under one condition variable (its lock is THE lock of
    ``ProcDataServer``: total / tickets / in-flight move together)."""

    def __init__(self, *, n_collectors: int = 1, maxsize: int = 512,
                 target: Optional[int] = None):
        self.cond = threading.Condition()
        self.items: deque = deque()          # of (n_lanes, tree-frame bytes)
        self.maxsize = int(maxsize)
        self.n_collectors = max(int(n_collectors), 1)
        self.total = 0
        self.target = None if target is None else int(target)
        self.tickets = 0
        self.inflight: Dict[int, int] = {}

    def push(self, collector_id: int, n: int, blob: bytes,
             timeout: float) -> Optional[int]:
        """Enqueue ``n`` lanes as one item; waits up to ``timeout`` for
        queue space, returns the new total or None (full — the client
        raises BackpressureError)."""
        with self.cond:
            if not self.cond.wait_for(
                    lambda: len(self.items) < self.maxsize, timeout):
                return None
            self.items.append((int(n), blob))
            self.total += int(n)
            left = self.inflight.get(collector_id, 0) - int(n)
            if left > 0:
                self.inflight[collector_id] = left
            else:
                self.inflight.pop(collector_id, None)
            self.cond.notify_all()
            return self.total

    def claim(self, collector_id: int, k: int) -> int:
        with self.cond:
            g = k if self.target is None else \
                min(k, max(self.target - self.tickets, 0))
            if g > 0:
                self.tickets += g
                self.inflight[collector_id] = \
                    self.inflight.get(collector_id, 0) + g
            return g

    def refund(self, collector_id: int) -> int:
        with self.cond:
            g = self.inflight.pop(collector_id, 0)
            self.tickets -= g
            return g

    def drain(self) -> List[Tuple[int, bytes]]:
        with self.cond:
            items = list(self.items)
            self.items.clear()
            self.cond.notify_all()
            return items

    def set_target(self, total: int) -> None:
        with self.cond:
            self.target = int(total)
            self.tickets = self.total


class ControlPlane:
    """The socket transport's server: one TCP listener, N parameter
    stores, one data plane, a hand-rolled thread-per-connection loop
    (daemon threads; a wedged peer can never hang teardown).

    ``parameter_server(name, template)`` / ``data_server(...)`` register
    server-side state AND return the matching in-process client — the
    trainer talks to its own plane through the same TCP path remote
    collectors use, so one code path is exercised everywhere.
    """

    def __init__(self, bind: str = "127.0.0.1:0"):
        host, port = parse_addr(bind)
        self._srv = socket.create_server((host, port))
        self.addr: Tuple[str, int] = self._srv.getsockname()[:2]
        self._stores: List[_ParamStore] = []
        self._store_ids: Dict[str, int] = {}
        self.data: Optional[_DataPlane] = None
        self._join_blob: Optional[bytes] = None
        self._join_meta: Dict[str, object] = {}
        self._next_join_id = 1
        self._lock = threading.Lock()
        self._conns: set = set()
        self._closed = False
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="control-plane-accept",
            daemon=True)
        self._accept_thread.start()

    # -- registration / client factories --------------------------------
    @property
    def connect_addr(self) -> Tuple[str, int]:
        """Address clients should dial: a 0.0.0.0 bind is reachable
        locally via loopback."""
        host, port = self.addr
        return ("127.0.0.1" if host in ("0.0.0.0", "::") else host, port)

    def parameter_server(self, name: str, template=None):
        """Register a named parameter store and return its client.
        ``template`` fixes the LeafCodec now (procs mode: the parent
        knows the params); without it the codec is built lazily from
        the first push (threads mode: workers are built after the
        servers)."""
        from repro.net.client import TcpParameterServer
        codec_blob = None
        if template is not None:
            from repro.checkpoint.io import LeafCodec
            codec_blob = pickle.dumps(LeafCodec(template))
        with self._lock:
            sid = self._store_ids.setdefault(name, len(self._stores))
            if sid == len(self._stores):
                self._stores.append(_ParamStore(codec_blob))
            elif codec_blob is not None:
                self._stores[sid].codec_blob = codec_blob
        return TcpParameterServer(self.connect_addr, sid, name,
                                  template=template)

    def data_server(self, *, n_collectors: int = 1, maxsize: int = 512,
                    push_timeout: float = 30.0,
                    target: Optional[int] = None,
                    claim_backoff: float = 0.002):
        """Arm the (single) data plane and return its client."""
        from repro.net.client import TcpDataServer
        self.data = _DataPlane(n_collectors=n_collectors, maxsize=maxsize,
                               target=target)
        self._join_meta.update(n_collectors=int(n_collectors),
                               push_timeout=float(push_timeout),
                               claim_backoff=float(claim_backoff))
        self._next_join_id = max(self._next_join_id, int(n_collectors))
        return TcpDataServer(self.connect_addr,
                             n_collectors=n_collectors,
                             push_timeout=push_timeout,
                             claim_backoff=claim_backoff)

    def set_join_spec(self, blob: bytes) -> None:
        """Publish the pickled worker spec remote joiners rebuild from
        (``--connect``). Pickle over a TRUSTED link only — see
        docs/WIRE_PROTOCOL.md."""
        self._join_blob = blob

    # -- server loop -----------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return                      # listener closed
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                if self._closed:
                    conn.close()
                    return
                self._conns.add(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="control-plane-conn",
                             daemon=True).start()

    def _serve_conn(self, conn) -> None:
        try:
            while True:
                try:
                    op, word, aux, flags, payload = F.recv_frame(conn)
                except (F.ProtocolError, OSError):
                    return                  # peer died / torn frame
                try:
                    self._dispatch(conn, op, word, aux, flags, payload)
                except (BrokenPipeError, ConnectionError, OSError):
                    return
                except Exception as e:      # noqa: BLE001 — reply, don't die
                    try:
                        F.send_frame(conn, F.OP_ERR,
                                     payload=str(e).encode())
                    except OSError:
                        return
        finally:
            with self._lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _store(self, sid: int) -> _ParamStore:
        with self._lock:
            return self._stores[sid]

    def _dispatch(self, conn, op, word, aux, flags, payload) -> None:
        if op == F.OP_PPUSH:
            F.send_frame(conn, F.OP_OK,
                         word=self._store(aux).push(payload))
        elif op == F.OP_PPULL:
            blob, ver = self._store(aux).pull(word)
            F.send_frame(conn, F.OP_OK, word=ver, payload=blob or b"")
        elif op == F.OP_PVER:
            F.send_frame(conn, F.OP_OK, word=self._store(aux).version)
        elif op == F.OP_PMETA:
            blob = self._store(aux).codec_blob
            if blob is None:
                raise RuntimeError(f"store {aux} has no codec yet "
                                   "(nothing pushed)")
            F.send_frame(conn, F.OP_OK, payload=blob)
        elif op == F.OP_PINIT:
            store = self._store(aux)
            if store.codec_blob is None:
                store.codec_blob = payload
            F.send_frame(conn, F.OP_OK)
        elif op == F.OP_DPUSH:
            total = self.data.push(aux, flags, payload, word / 1000.0)
            if total is None:
                F.send_frame(conn, F.OP_FULL, word=self.data.maxsize)
            else:
                F.send_frame(conn, F.OP_OK, word=total)
        elif op == F.OP_DCLAIM:
            F.send_frame(conn, F.OP_OK, word=self.data.claim(aux, word))
        elif op == F.OP_DREFUND:
            F.send_frame(conn, F.OP_OK, word=self.data.refund(aux))
        elif op == F.OP_DDRAIN:
            items = self.data.drain()
            F.send_frame(conn, F.OP_OK, word=len(items),
                         payload=F.pack_drain_items(items))
        elif op == F.OP_DTOTAL:
            with self.data.cond:
                F.send_frame(conn, F.OP_OK, word=self.data.total)
        elif op == F.OP_DTARGET:
            self.data.set_target(word)
            F.send_frame(conn, F.OP_OK)
        elif op == F.OP_DLEN:
            with self.data.cond:
                F.send_frame(conn, F.OP_OK, word=len(self.data.items))
        elif op == F.OP_JOIN:
            if self._join_blob is None:
                raise RuntimeError("no join spec published on this plane")
            with self._lock:
                cid = self._next_join_id
                self._next_join_id += 1
            ticket = dict(self._join_meta)
            ticket.update(spec=self._join_blob, collector_id=cid,
                          stores=dict(self._store_ids))
            F.send_frame(conn, F.OP_OK, word=cid,
                         payload=pickle.dumps(ticket))
        else:
            raise RuntimeError(f"unknown opcode {op}")

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        """Shut the listener and every live connection. Idempotent;
        daemon handler threads exit on their next read."""
        if self._closed:
            return
        self._closed = True
        try:
            self._srv.close()
        except OSError:
            pass
        with self._lock:
            conns, self._conns = list(self._conns), set()
        for c in conns:
            try:
                c.close()
            except OSError:
                pass

    def __enter__(self) -> "ControlPlane":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
