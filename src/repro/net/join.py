"""Remote collector entry (`--connect host:port`).

A joining process asks the run's ControlPlane for a JOIN ticket — the
pickled :class:`~repro.core.workers.ProcSpec` the trainer published, a
fresh collector id (allocated past the trainer's own fleet), and the
store-name -> id map — rebuilds a :class:`DataCollectionWorker` locally
exactly like a spawned procs-mode child, and runs the standard
claim -> collect -> push loop against the plane until the global
criterion is fully claimed.

Exactness across the boundary: joiners claim from the SAME ticket
counters as the local fleet, so they can never overshoot the criterion;
a joiner that dies between claim and push leaves its tickets in flight
on the plane, refundable exactly once via ``refund_inflight(id)`` (the
joiner refunds its own on any clean or error exit; a SIGKILLed joiner's
tickets must be refunded by an operator or the trainer on timeout —
the plane never auto-refunds a disconnect, see net/control.py).

Trust: the JOIN ticket is a pickle — connect only to planes you run
(docs/WIRE_PROTOCOL.md 'Security model').
"""
from __future__ import annotations

import pickle
import threading
import time
from typing import List

from repro.net import frame as F
from repro.net.client import TcpDataServer, TcpParameterServer, _TcpHandle
from repro.net.control import parse_addr


def request_join_ticket(addr) -> dict:
    """One JOIN RPC -> {spec, collector_id, stores, n_collectors,
    push_timeout, claim_backoff}. Each call allocates a fresh id."""
    with _TcpHandle(tuple(addr)) as h:
        _, cid, _, _, payload = h._rpc(F.OP_JOIN)
    ticket = pickle.loads(payload)
    ticket["collector_id"] = int(cid)
    return ticket


def _run_joined_collector(addr, ticket, counts: List[int], idx: int):
    import jax

    from repro.core.workers import DataCollectionWorker, ExplorationSchedule
    spec = pickle.loads(ticket["spec"])
    rc = spec.run_cfg
    cid = int(ticket["collector_id"])
    sched = spec.exploration or ExplorationSchedule()
    policy_srv = TcpParameterServer(addr, ticket["stores"]["policy"],
                                    "policy")
    data = TcpDataServer(addr,
                         n_collectors=ticket.get("n_collectors", 1),
                         push_timeout=ticket.get("push_timeout", 30.0),
                         claim_backoff=ticket.get("claim_backoff", 0.002))
    # same base collector key as every engine (split(key(seed), 4)[0]);
    # the worker folds the collector id in itself, so a joiner's stream
    # matches a local fleet member with the same id
    key = jax.random.split(jax.random.key(spec.seed), 4)[0]
    w = DataCollectionWorker(spec.env, policy_srv, data, None, key,
                             speed=rc.collect_speed, collector_id=cid,
                             noise_scale=sched.scale_for(cid),
                             envs_per_step=rc.envs_per_collector)
    try:
        # warmup: claim nothing until a policy exists — a claimed ticket
        # must always be fulfilled by the very next step
        while not w.poll_policy():
            time.sleep(0.005)
        while True:
            g = data.try_claim(cid, k=w.envs_per_step)
            if not g:
                break               # global target fully claimed: done
            t_step = time.monotonic()
            dur = w.step(g)
            if rc.pace_collection and dur is not None:
                time.sleep(max(dur - (time.monotonic() - t_step), 0.0))
    except (F.ProtocolError, OSError):
        # plane unreachable: refund our own in-flight tickets so the
        # criterion does not stall on this joiner, then stop
        try:
            data.refund_inflight(cid)
        except (F.ProtocolError, OSError):
            pass
    finally:
        counts[idx] = w.collected
        policy_srv.close()
        data.close()


def join_as_collectors(addr: str, *, n_collectors: int = 1) -> int:
    """Join a live run at ``addr`` ('host:port') as ``n_collectors``
    additional remote collectors (one thread each, one JOIN ticket and
    one collector id each). Blocks until the run's global criterion is
    fully claimed; returns the number of trajectories THIS process
    contributed."""
    target = parse_addr(addr)
    counts = [0] * int(n_collectors)
    threads = []
    for i in range(int(n_collectors)):
        ticket = request_join_ticket(target)
        th = threading.Thread(target=_run_joined_collector,
                              args=(target, ticket, counts, i),
                              name=f"join-collector:{ticket['collector_id']}",
                              daemon=True)
        th.start()
        threads.append(th)
    for th in threads:
        th.join()
    return sum(counts)
