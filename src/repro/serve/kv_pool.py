"""Paged KV pool: static-shape slot storage + page-ledger admission.

Two layers, deliberately separated:

* STORAGE is slot-dense and compile-once — one cache pytree allocated at
  ``(n_slots, max_seq)`` via ``lm.init_cache_slots`` and mutated only by
  jitted donating updates (the ReplayBuffer static-shape idiom: shapes
  never change as requests churn, so nothing ever retraces). Admission
  scatters a prefilled single-request cache into a slot row — one
  compile per prompt bucket, counted.

* ACCOUNTING is paged — a fixed pool of ``n_pages`` pages of
  ``page_len`` token slots each. A request must hold
  ``ceil((prompt + max_new) / page_len)`` pages for its whole lifetime
  before it may occupy a slot, and retirement returns them. This makes
  admission memory-bounded (a request can be refused on page exhaustion
  even with slots free) and conservation checkable:
  ``free + held == n_pages`` always.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm as LM
from repro.models.api import grow_cache
from repro.utils.jit_stats import trace_counted


def _admit_update(pool, pre, slot):
    """Scatter one prefilled request cache (B=1, any bucket length) into
    pool slot ``slot``. The bucket cache is grown to pool capacity
    INSIDE the jit (static pad), and every per-slot field — k, v, pos,
    index — is fully overwritten, so no stale tenant state survives an
    admission."""
    pre = grow_cache(pre, pool["k"].shape[2])
    out = dict(pool)
    out["k"] = jax.lax.dynamic_update_slice(
        pool["k"], pre["k"].astype(pool["k"].dtype), (0, slot, 0, 0, 0))
    out["v"] = jax.lax.dynamic_update_slice(
        pool["v"], pre["v"].astype(pool["v"].dtype), (0, slot, 0, 0, 0))
    out["pos"] = jax.lax.dynamic_update_slice(pool["pos"], pre["pos"],
                                              (slot, 0))
    out["index"] = jax.lax.dynamic_update_slice(pool["index"],
                                                pre["index"], (slot,))
    return out


class PagedKVPool:
    """Fixed page pool + per-request page tables over slot-dense storage.

    ``cache`` is the live decode cache pytree (handed to / returned by
    the serve decode bundle each tick, donated both ways). Slots and
    pages are host-side bookkeeping; the device arrays never reshape.
    """

    def __init__(self, cfg, ctx, *, n_slots: int, max_seq: int,
                 page_len: int = 16, n_pages: int = None,
                 cache_shardings=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.page_len = int(page_len)
        self.cache = LM.init_cache_slots(cfg, ctx, n_slots, max_seq)
        if cache_shardings is not None:
            self.cache = jax.device_put(self.cache, cache_shardings)
        self.s_cache = self.cache["k"].shape[2]
        full = n_slots * self.pages_for(self.s_cache)
        self.n_pages = full if n_pages is None else int(n_pages)
        self._free_pages = list(range(self.n_pages))
        self._free_slots = list(range(n_slots))
        self._page_table: Dict[int, Tuple[int, ...]] = {}
        jit_kw = {"donate_argnums": (0,)}
        if cache_shardings is not None:
            jit_kw["out_shardings"] = cache_shardings
        self._admit = trace_counted(_admit_update, **jit_kw)

    def pages_for(self, tokens: int) -> int:
        return -(-int(tokens) // self.page_len)

    def can_admit(self, budget_tokens: int) -> bool:
        """One free slot AND enough free pages for the request's whole
        token budget (prompt + max_new) — held until retirement."""
        return (bool(self._free_slots)
                and self.pages_for(budget_tokens) <= len(self._free_pages))

    def admit(self, pre_cache, budget_tokens: int) -> int:
        """Claim a slot + pages and scatter the prefilled cache in.
        Returns the slot id. Callers check :meth:`can_admit` first."""
        if budget_tokens > self.s_cache:
            raise ValueError(
                f"request budget {budget_tokens} tokens exceeds pool "
                f"capacity {self.s_cache}")
        need = self.pages_for(budget_tokens)
        if not self._free_slots:
            raise RuntimeError("no free decode slot")
        if need > len(self._free_pages):
            raise RuntimeError(
                f"page pool exhausted: need {need}, "
                f"free {len(self._free_pages)}/{self.n_pages}")
        slot = self._free_slots.pop(0)
        self._page_table[slot] = tuple(self._free_pages[:need])
        del self._free_pages[:need]
        self.cache = self._admit(self.cache, pre_cache,
                                 jnp.asarray(slot, jnp.int32))
        return slot

    def retire(self, slot: int) -> None:
        """Free a slot's pages. Storage needs no cleanup: the slot row is
        fully overwritten by the next admission, and the decode step's
        drop-mode scatter never writes inactive slots."""
        self._free_pages.extend(self._page_table.pop(slot))
        self._free_slots.append(slot)

    def accounting(self) -> Tuple[int, int]:
        """(free_pages, held_pages); their sum must equal n_pages."""
        held = sum(len(p) for p in self._page_table.values())
        return len(self._free_pages), held

    @property
    def admit_compiles(self) -> int:
        return self._admit.trace_count
