"""WorldModelServer: the user-facing serving tier.

Wires three pieces together:

* a bounded :class:`RequestQueue` — the ``ProcDataServer`` admission
  contract (bounded + timeout + descriptive ``BackpressureError``)
  brought in-process;
* the continuous-batching :class:`~repro.serve.scheduler.Scheduler`
  over its paged KV pool;
* live hot-swap — between decode ticks the server runs one
  ``ParameterServer.pull_if_newer(version)``: the unchanged path is a
  lock + int compare with ZERO transfers, a version change re-homes the
  new weights onto the decode bundle's shardings and the very next tick
  decodes with them. Caches survive the swap untouched (KV entries are
  a function of the prompt under the weights that wrote them; requests
  in flight continue seamlessly at the new version).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.servers import BackpressureError
from repro.launch.mesh import make_smoke_mesh
from repro.serve.scheduler import Request, Scheduler


class RequestQueue:
    """Bounded FIFO admission queue. ``submit`` blocks up to ``timeout``
    seconds for space, then raises :class:`BackpressureError` — the same
    shed-load signal the trajectory path uses, so callers handle both
    tiers identically."""

    def __init__(self, maxsize: int = 64, submit_timeout: float = 0.0):
        self.maxsize = int(maxsize)
        self.submit_timeout = float(submit_timeout)
        self._dq = collections.deque()
        self._cv = threading.Condition()

    def submit(self, req: Request, timeout: Optional[float] = None) -> None:
        timeout = self.submit_timeout if timeout is None else timeout
        deadline = time.monotonic() + timeout
        with self._cv:
            while len(self._dq) >= self.maxsize:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise BackpressureError(
                        f"serve request queue full ({self.maxsize} "
                        f"waiting) after {timeout:.1f}s: the decode loop "
                        f"is not draining admissions fast enough — scale "
                        f"n_slots / the page pool, or shed load")
                self._cv.wait(left)
            self._dq.append(req)

    def pop(self) -> Request:
        with self._cv:
            req = self._dq.popleft()
            self._cv.notify_all()
            return req

    def peek(self) -> Optional[Request]:
        with self._cv:
            return self._dq[0] if self._dq else None

    def __len__(self) -> int:
        with self._cv:
            return len(self._dq)


class WorldModelServer:
    """Continuous-batching world-model inference with live hot-swap.

    Construct with either fixed ``params`` or a ``param_server`` (any
    object with ``pull()`` / ``pull_if_newer(version, sharding=...)`` —
    the training fleet's ParameterServer or ShmParameterServer). With a
    param server, every ``step()`` begins with a version-gated pull.
    """

    def __init__(self, cfg, mesh=None, *, params=None, param_server=None,
                 n_slots: int = 4, max_seq: int = 96, page_len: int = 16,
                 n_pages: int = None, prompt_buckets=(16, 32, 64),
                 queue_maxsize: int = 64, submit_timeout: float = 0.0):
        if (params is None) == (param_server is None):
            raise ValueError("pass exactly one of params= / param_server=")
        mesh = make_smoke_mesh() if mesh is None else mesh
        self.sched = Scheduler(cfg, mesh, n_slots=n_slots, max_seq=max_seq,
                               page_len=page_len, n_pages=n_pages,
                               prompt_buckets=prompt_buckets)
        self.queue = RequestQueue(queue_maxsize, submit_timeout)
        self.param_server = param_server
        self.version = -1
        self.swaps = 0
        self.swap_seconds: List[float] = []
        self._params = params
        if params is not None:
            self.version = 0
        else:
            val, ver = param_server.pull()
            if val is None:
                raise ValueError("param_server has no pushed value yet")
            self._set_params(val, ver)
        self._rid = 0
        self._results: Dict[int, np.ndarray] = {}

    # -- params / hot-swap -------------------------------------------------

    def _set_params(self, val, ver: int) -> None:
        import jax  # local: server.py stays importable without a backend
        self._params = jax.device_put(val, self.sched.dec.in_shardings[0])
        self.version = ver

    def maybe_swap(self) -> bool:
        """One version-gated pull. Unchanged version: zero transfers
        (passes jax.transfer_guard('disallow')). Newer version: re-home
        and swap the pointer — in-flight requests pick it up on the very
        next decode tick."""
        if self.param_server is None:
            return False
        t0 = time.perf_counter()
        val, ver = self.param_server.pull_if_newer(
            self.version, sharding=self.sched.dec.in_shardings[0])
        if val is None:
            return False
        self._set_params(val, ver)
        self.swap_seconds.append(time.perf_counter() - t0)
        self.swaps += 1
        return True

    # -- request lifecycle -------------------------------------------------

    def submit(self, prompt, max_new: int,
               timeout: Optional[float] = None) -> int:
        """Enqueue one request; returns its rid. Raises ValueError for
        requests that could NEVER be served (too-long prompt, budget
        beyond pool capacity) and BackpressureError when the queue stays
        full past the timeout."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        bucket = self.sched.bucket_for(prompt.size)
        if bucket is None:
            raise ValueError(
                f"prompt of {prompt.size} tokens exceeds the largest "
                f"prefill bucket {self.sched.buckets[-1]}")
        budget = prompt.size + int(max_new)
        if budget > self.sched.pool.s_cache:
            raise ValueError(
                f"budget {budget} tokens exceeds pool capacity "
                f"{self.sched.pool.s_cache}")
        if self.sched.pool.pages_for(budget) > self.sched.pool.n_pages:
            raise ValueError(
                f"budget {budget} tokens needs more pages than the pool "
                f"holds ({self.sched.pool.n_pages})")
        rid = self._rid
        self._rid += 1
        req = Request(rid=rid, prompt=prompt, max_new=int(max_new),
                      bucket=bucket, submitted_s=time.perf_counter())
        self.queue.submit(req, timeout=timeout)
        return rid

    def step(self) -> int:
        """One serving round: hot-swap check, then one scheduler tick.
        Returns how many requests finished this step."""
        self.maybe_swap()
        finished = self.sched.tick(self._params, self.queue)
        for req in finished:
            self._results[req.rid] = np.asarray(req.tokens, np.int32)
        return len(finished)

    @property
    def pending(self) -> bool:
        return len(self.queue) > 0 or self.sched.busy

    def run(self, max_ticks: int = 100_000) -> int:
        """Drain queue + slots; returns ticks used. Every submitted
        request has a bounded budget, so this always terminates unless
        the scheduler stops making progress (then: RuntimeError)."""
        n = 0
        while self.pending:
            if n >= max_ticks:
                raise RuntimeError(f"serve run not drained after {n} ticks")
            before = (len(self.queue), self.sched.tokens_out)
            self.step()
            n += 1
            if (len(self.queue), self.sched.tokens_out) == before:
                raise RuntimeError(
                    "serve tick made no progress (queue head can never "
                    "fit? — submit() validation should have caught this)")
        return n

    def result(self, rid: int) -> np.ndarray:
        return self._results[rid]

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        ticks = self.sched.tick_seconds
        lat = sorted(dt for dt, _ in ticks)
        tok = sum(n for _, n in ticks)
        wall = sum(dt for dt, _ in ticks)

        def pct(p):
            return lat[min(len(lat) - 1, int(p * len(lat)))] if lat else 0.0

        cc = self.sched.compile_counts()
        return {
            "tokens_generated": self.sched.tokens_out,
            "decode_ticks": len(ticks),
            "tokens_per_s": (tok / wall) if wall > 0 else 0.0,
            "p50_ms_per_token": pct(0.50) * 1e3,
            "p95_ms_per_token": pct(0.95) * 1e3,
            "hot_swaps": self.swaps,
            "hotswap_stall_ms": (np.mean(self.swap_seconds) * 1e3
                                 if self.swap_seconds else 0.0),
            "decode_compiles": cc["decode"],
            "prefill_compiles": cc["prefill"],
            "admit_compiles": cc["admit"],
            "version": self.version,
        }
