"""CLI demo of the serving tier on the reduced world model.

    PYTHONPATH=src python -m repro.serve [--requests 12] [--n-slots 4]

Submits a stream of random-token requests with mixed prompt lengths,
serves them with continuous batching, hot-swaps the model once mid-run
(simulating a training push), and prints the server stats as JSON.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core.servers import ParameterServer
from repro.launch.mesh import make_smoke_mesh
from repro.models import api
from repro.serve import WorldModelServer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serve",
                                 description=__doc__)
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    key_w, key_w2 = jax.random.split(jax.random.key(args.seed))
    server_params = ParameterServer()
    ctx = api.shard_ctx(make_smoke_mesh())
    server_params.push(api._mod(cfg).init_params(cfg, ctx, key_w))
    srv = WorldModelServer(cfg, param_server=server_params,
                           n_slots=args.n_slots, max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    rids = []
    for i in range(args.requests):
        plen = int(rng.integers(4, srv.sched.buckets[-1] + 1))
        prompt = rng.integers(0, cfg.vocab_size, plen)
        rids.append(srv.submit(prompt, max_new=args.max_new))
        srv.step()
        if i == args.requests // 2:  # a mid-run training push
            server_params.push(api._mod(cfg).init_params(cfg, ctx, key_w2))
    srv.run()

    for rid in rids[:3]:
        print(f"request {rid}: {srv.result(rid).tolist()}")
    print(json.dumps(srv.stats(), indent=2, default=float))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
