"""Continuous-batching scheduler: admit into free slots, decode every tick.

The lock-step example (examples/serve_world_model.py) prefills one batch
and decodes it in unison — nothing can join until the whole batch
drains. This scheduler instead runs ONE decode program at a fixed slot
count forever and streams requests through it:

    tick := [admit queue head while it fits] ->
            [decode all active slots]        ->
            [emit one token per slot, retire finished]

Admission is strictly FIFO with head-of-line blocking (asserted in
tests): a request that does not fit — no free slot, or the page ledger
is short — blocks everything behind it, which keeps admission order
deterministic and starvation-free. Prompts are right-padded into a fixed
set of PREFILL BUCKETS, so compile counts are bounded by construction:
one decode compile, at most one prefill (and one admit-scatter) compile
per bucket, regardless of how many requests churn through.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.serve.kv_pool import PagedKVPool


@dataclasses.dataclass
class Request:
    """One generation request: ``prompt`` token ids in, ``tokens`` out
    (greedy continuation, exactly ``max_new`` long)."""
    rid: int
    prompt: np.ndarray
    max_new: int
    bucket: int = -1
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    admitted_s: float = 0.0
    done_s: float = 0.0

    @property
    def budget(self) -> int:
        """Token slots this request may ever occupy (drives paging)."""
        return len(self.prompt) + self.max_new

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new


class Scheduler:
    """Owns the compiled step functions, the KV pool and the slot<->
    request binding. Parameters are passed into every tick — versioning
    and hot-swap live one level up in WorldModelServer."""

    def __init__(self, cfg, mesh, *, n_slots: int, max_seq: int,
                 page_len: int = 16, n_pages: int = None,
                 prompt_buckets=(16, 32, 64)):
        buckets = tuple(sorted(set(int(b) for b in prompt_buckets)))
        if not buckets:
            raise ValueError("need at least one prompt bucket")
        if buckets[-1] > max_seq:
            raise ValueError(f"largest bucket {buckets[-1]} exceeds "
                             f"max_seq {max_seq}")
        self.cfg = cfg
        self.buckets = buckets
        self.n_slots = n_slots
        self.dec = api.build_serve_decode(cfg, mesh, n_slots, max_seq)
        self.pre = {b: api.build_serve_prefill(cfg, mesh, 1, b)
                    for b in buckets}
        self.pool = PagedKVPool(cfg, self.dec.ctx, n_slots=n_slots,
                                max_seq=max_seq, page_len=page_len,
                                n_pages=n_pages,
                                cache_shardings=self.dec.in_shardings[1])
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self._tok = np.zeros((n_slots, 1), np.int32)
        self._act = np.zeros((n_slots,), bool)
        self.ticks = 0
        self.tokens_out = 0
        self.admit_order: List[int] = []
        self.tick_seconds: List[tuple] = []  # (seconds, n_active)

    # -- admission ---------------------------------------------------------

    def bucket_for(self, prompt_len: int) -> Optional[int]:
        for b in self.buckets:
            if prompt_len <= b:
                return b
        return None

    def fits(self, req: Request) -> bool:
        return self.pool.can_admit(req.budget)

    def _admit(self, params, req: Request) -> None:
        b = req.bucket
        batch = np.zeros((1, b), np.int32)
        batch[0, :len(req.prompt)] = req.prompt
        plen = jnp.asarray([len(req.prompt)], jnp.int32)
        logits, pre_cache = self.pre[b].fn(
            params, {"tokens": jnp.asarray(batch)}, plen)
        slot = self.pool.admit(pre_cache, req.budget)
        req.slot = slot
        req.admitted_s = time.perf_counter()
        self.admit_order.append(req.rid)
        self.slot_req[slot] = req
        t0 = int(np.asarray(
            jnp.argmax(logits[0, :self.cfg.vocab_size])))
        req.tokens.append(t0)
        self.tokens_out += 1
        self._tok[slot, 0] = t0
        self._act[slot] = True

    def _retire(self, req: Request) -> None:
        req.done_s = time.perf_counter()
        self.pool.retire(req.slot)
        self.slot_req[req.slot] = None
        self._act[req.slot] = False

    # -- the tick ----------------------------------------------------------

    def tick(self, params, queue) -> List[Request]:
        """One scheduler round. Returns the requests finished this tick.
        ``queue`` needs ``__len__``, ``peek()`` and ``pop()``."""
        self.ticks += 1
        finished: List[Request] = []
        while len(queue) and self.fits(queue.peek()):
            req = queue.pop()
            self._admit(params, req)
            if req.done:  # max_new == 1: satisfied by the prefill logits
                self._retire(req)
                finished.append(req)
        if not self._act.any():
            return finished

        t0 = time.perf_counter()
        logits, self.pool.cache = self.dec.fn(
            params, self.pool.cache, jnp.asarray(self._tok),
            jnp.asarray(self._act))
        nxt = np.asarray(jnp.argmax(logits[:, :self.cfg.vocab_size], -1),
                         dtype=np.int32)  # host sync point
        n_active = int(self._act.sum())
        self.tick_seconds.append((time.perf_counter() - t0, n_active))

        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.tokens.append(int(nxt[slot]))
            self.tokens_out += 1
            self._tok[slot, 0] = nxt[slot]
            if req.done:
                self._retire(req)
                finished.append(req)
        return finished

    @property
    def busy(self) -> bool:
        return any(r is not None for r in self.slot_req)

    def compile_counts(self) -> dict:
        return {
            "decode": self.dec.fn.trace_count,
            "prefill": sum(b.fn.trace_count for b in self.pre.values()),
            "admit": self.pool.admit_compiles,
        }
