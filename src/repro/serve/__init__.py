"""World-model serving tier: continuous batching + paged KV cache +
live hot-swap.

The training fleet's async contract extended to inference: the server is
just another ``ParameterServer.pull_if_newer`` consumer, so the fleet
trains while serving picks up each push with zero downtime and zero
copies on unchanged versions.

    submit() -> RequestQueue (bounded, BackpressureError)
            -> Scheduler (continuous batching over a PagedKVPool)
            -> pull_if_newer (hot-swap between decode ticks)

See README "Serving" and ROADMAP "Serving-tier invariants (PR 8)".
"""
from repro.serve.kv_pool import PagedKVPool
from repro.serve.scheduler import Request, Scheduler
from repro.serve.server import RequestQueue, WorldModelServer

__all__ = ["PagedKVPool", "Request", "RequestQueue", "Scheduler",
           "WorldModelServer"]
