"""Chaos engine + soak harness (PR 7).

The paper's claim — async MBRL keeps learning while the real world keeps
moving — only holds in production if the trainer survives crashes,
stalls, and slow consumers WITHOUT violating any PR 1-6 invariant. This
package turns that into a continuously-checked property:

* :mod:`repro.chaos.faults` — ``FaultPlan`` (deterministic, seeded fault
  schedules: SIGKILLs across every role incl. fleet collectors,
  SIGSTOP/SIGCONT stalls that saturate the queue / slow consumers,
  delayed respawns) and ``ChaosSupervisor``, which injects the plan
  through the :class:`repro.core.runtime.Supervisor` seam.
* :mod:`repro.chaos.monitor` — ``InvariantMonitor``: always-on checks
  DURING the run (exact criterion with refunds, strictly monotone
  versions across restarts, zero retraces after warmup, bounded restart
  budgets).
* :mod:`repro.chaos.audit` — ``ResourceAuditor``: proves zero leaked
  shm segments / fds / child processes after clean AND chaotic
  shutdown (sweeps ``/dev/shm`` + ``/proc/self/fd`` deltas and the
  server audit registries).
* :mod:`repro.chaos.soak` — ``python -m repro.chaos.soak`` CLI tying it
  together; profiles for PR CI (``short``) and scheduled jobs
  (``long``); machine-readable ``SOAK_report.json``.
"""
from repro.chaos.audit import ResourceAuditor
from repro.chaos.faults import KILL, STALL, ChaosSupervisor, FaultEvent, \
    FaultPlan
from repro.chaos.monitor import InvariantMonitor

__all__ = ["ChaosSupervisor", "FaultEvent", "FaultPlan",
           "InvariantMonitor", "KILL", "PROFILES", "ResourceAuditor",
           "STALL", "run_soak"]


def __getattr__(name):
    # soak imports lazily: `python -m repro.chaos.soak` first imports
    # this package, and an eager soak import there would double-import
    # the __main__ module (runpy's RuntimeWarning)
    if name in ("PROFILES", "run_soak"):
        from repro.chaos import soak
        return getattr(soak, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
