"""Deterministic, seeded fault injection for ``AsyncTrainer(mode="procs")``.

A :class:`FaultPlan` is a pure function of its seed: a sorted tuple of
:class:`FaultEvent`, each timed as a PROGRESS FRACTION of the global
``total_trajs`` criterion (not wall seconds — progress is the one clock
every run shares, so the same plan exercises the same run phases on a
loaded CI host and a fast workstation alike). Fault kinds:

* ``kill`` — SIGKILL the role's child mid-flight; ``arg`` seconds of
  supervisor-side respawn delay make the role stay DOWN, not just
  bounce (crash + delayed respawn + restart-from-snapshot under fire).
* ``stall`` — SIGSTOP the child for ``arg`` seconds, then SIGCONT. A
  stalled model worker is the paper's "slow consumer": the trajectory
  queue saturates and collectors ride the backpressure path; a stalled
  collector is a robot dropping off the fleet (Gu et al.).

:class:`ChaosSupervisor` replays the plan through the
:class:`repro.core.runtime.Supervisor` seam — the trainer itself knows
nothing about chaos. Injection is budget-aware (a kill is skipped, and
recorded as skipped, when the role has no ``max_restarts`` headroom
left) and liveness-aware (an event for a role that is currently down or
already stalled is DEFERRED to the next tick, not dropped), so a
well-formed plan always leaves the run completable: the acceptance bar
is ≥ 10 injected faults across all three roles with ZERO invariant
violations.
"""
from __future__ import annotations

import dataclasses
import os
import random
import signal
import time
from typing import Any, Dict, List, Tuple

from repro.core.runtime import Supervisor

KILL = "kill"
STALL = "stall"

_FAMILIES = ("model", "policy", "collector")


def role_family(role: str) -> str:
    """``collector:3`` -> ``collector``; learners map to themselves."""
    return "collector" if role.startswith("collector") else role


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    at: float           # progress fraction (total_pushed/total_trajs)
    kind: str           # KILL | STALL
    role: str           # "model" | "policy" | "collector:<i>"
    arg: float = 0.0    # KILL: respawn delay (s); STALL: duration (s)

    def to_dict(self) -> Dict[str, Any]:
        return {"at": self.at, "kind": self.kind, "role": self.role,
                "arg": self.arg}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded fault schedule. ``generate`` is deterministic: same
    (seed, shape kwargs) -> identical plan, so a failing soak reproduces
    exactly from its reported seed."""

    seed: int
    events: Tuple[FaultEvent, ...]

    @staticmethod
    def generate(seed: int, *, n_collectors: int, n_faults: int = 12,
                 max_kills_per_role: int = 3,
                 window: Tuple[float, float] = (0.05, 0.85),
                 stall_s: Tuple[float, float] = (0.15, 0.8),
                 respawn_delay_s: Tuple[float, float] = (0.0, 0.4),
                 kill_fraction: float = 0.6) -> "FaultPlan":
        """Draw ``n_faults`` events covering ALL role families.

        Guarantees, independent of seed:
        * the first three events target model, policy, and a collector
          (one per family), so coverage never depends on luck;
        * kills per role never exceed ``max_kills_per_role`` — keep that
          ``<= RunConfig.max_restarts`` and the plan can never exhaust a
          restart budget by itself;
        * both kinds appear (a kill-only or stall-only draw is repaired
          deterministically);
        * every ``at`` lies inside ``window`` — strictly before the
          criterion lands, so no event waits on progress that will
          never come.
        """
        lo, hi = window
        assert 0.0 < lo < hi < 1.0, window
        rng = random.Random(seed)
        roles = ["model", "policy"] + \
            [f"collector:{i}" for i in range(n_collectors)]
        kills_left = {r: int(max_kills_per_role) for r in roles}
        events: List[FaultEvent] = []
        for i in range(int(n_faults)):
            if i < 3:   # guaranteed one event per role family
                role = ("model", "policy",
                        rng.choice(roles[2:]))[i]
            else:
                role = rng.choice(roles)
            want_kill = rng.random() < kill_fraction
            at = round(rng.uniform(lo, hi), 4)
            if want_kill and kills_left[role] > 0:
                kills_left[role] -= 1
                events.append(FaultEvent(
                    at, KILL, role, round(rng.uniform(*respawn_delay_s),
                                          3)))
            else:
                events.append(FaultEvent(
                    at, STALL, role, round(rng.uniform(*stall_s), 3)))
        kinds = {e.kind for e in events}
        if STALL not in kinds and events:
            e = events[-1]
            kills_left[e.role] += 1
            events[-1] = FaultEvent(e.at, STALL, e.role,
                                    round(rng.uniform(*stall_s), 3))
        if KILL not in kinds:
            for j, e in enumerate(events):
                if kills_left[e.role] > 0:
                    kills_left[e.role] -= 1
                    events[j] = FaultEvent(
                        e.at, KILL, e.role,
                        round(rng.uniform(*respawn_delay_s), 3))
                    break
        events.sort(key=lambda e: (e.at, e.role, e.kind))
        return FaultPlan(seed=int(seed), events=tuple(events))

    def families(self) -> Tuple[str, ...]:
        return tuple(sorted({role_family(e.role) for e in self.events}))


def _signal_proc(p, sig) -> bool:
    """Deliver ``sig`` to a live child; False if it died first."""
    try:
        os.kill(p.pid, sig)
        return True
    except (ProcessLookupError, PermissionError, TypeError):
        return False


class ChaosSupervisor(Supervisor):
    """Inject a :class:`FaultPlan` through the supervision seam.

    Bookkeeping (all plain dicts, JSON-ready for ``SOAK_report.json``):
    ``injected`` — faults actually delivered, with the progress and wall
    time they fired at; ``skipped`` — events dropped with a reason (no
    restart-budget headroom, or the run completed first). Deferred
    events (target currently down or already stalled) are retried every
    tick until injectable.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._queue: List[FaultEvent] = list(plan.events)
        self.injected: List[Dict[str, Any]] = []
        self.skipped: List[Dict[str, Any]] = []
        # role -> (proc, resume deadline) for in-flight stalls
        self._stalls: Dict[str, Tuple[Any, float]] = {}
        # role -> delay to apply to its NEXT crash-restart
        self._next_respawn_delay: Dict[str, float] = {}

    # ----------------------------------------------------------- seam
    def on_tick(self) -> None:
        now = time.monotonic()
        for role, (p, deadline) in list(self._stalls.items()):
            if now >= deadline:
                _signal_proc(p, signal.SIGCONT)
                del self._stalls[role]
        prog = self._progress()
        due = [e for e in self._queue if e.at <= prog]
        if not due:
            return
        deferred = []
        for ev in due:
            if not self._inject(ev, prog):
                deferred.append(ev)
        self._queue = deferred + [e for e in self._queue if e.at > prog]

    def respawn_delay(self, role: str) -> float:
        return self._next_respawn_delay.pop(role, 0.0)

    def on_complete(self) -> None:
        self._release_stalls()
        for ev in self._queue:      # whatever never became injectable
            self.skipped.append(
                {**ev.to_dict(), "reason": "run completed first"})
        self._queue.clear()

    def on_teardown(self, procs) -> None:
        # a SIGSTOPped child cannot handle the teardown SIGTERM — make
        # every child signalable again before the parent joins
        self._release_stalls()

    # -------------------------------------------------------- internals
    def _progress(self) -> float:
        tr = self.trainer
        return tr._proc_servers["data"].total_pushed / \
            max(tr.run_cfg.total_trajs, 1)

    def _release_stalls(self) -> None:
        for role, (p, _) in list(self._stalls.items()):
            _signal_proc(p, signal.SIGCONT)
        self._stalls.clear()

    def _inject(self, ev: FaultEvent, prog: float) -> bool:
        """True when the event is finished (injected or skipped); False
        to defer it to the next tick."""
        tr = self.trainer
        p = tr._procs.get(ev.role)
        if p is None or p.exitcode is not None:
            return False            # role down / mid-respawn: defer
        if ev.role in self._stalls:
            return False            # one stall at a time per role
        if ev.kind == KILL:
            rc = tr.run_cfg
            if tr.proc_info["restarts"].get(ev.role, 0) >= rc.max_restarts:
                self.skipped.append(
                    {**ev.to_dict(),
                     "reason": f"no headroom under max_restarts="
                               f"{rc.max_restarts}"})
                return True
            self._next_respawn_delay[ev.role] = float(ev.arg)
            if not _signal_proc(p, signal.SIGKILL):
                self._next_respawn_delay.pop(ev.role, None)
                return False
            self.injected.append(
                {**ev.to_dict(), "progress": round(prog, 4),
                 "t_monotonic": time.monotonic()})
            return True
        if not _signal_proc(p, signal.SIGSTOP):
            return False
        self._stalls[ev.role] = (p, time.monotonic() + float(ev.arg))
        self.injected.append(
            {**ev.to_dict(), "progress": round(prog, 4),
             "t_monotonic": time.monotonic()})
        return True

    # ---------------------------------------------------------- report
    def report(self) -> Dict[str, Any]:
        fams = sorted({role_family(f["role"]) for f in self.injected})
        return {"seed": self.plan.seed,
                "planned": [e.to_dict() for e in self.plan.events],
                "injected": list(self.injected),
                "skipped": list(self.skipped),
                "n_injected": len(self.injected),
                "families_injected": fams}
