"""Soak harness: seeded chaos runs with always-on invariant checks.

Usage::

    python -m repro.chaos.soak --profile short --seed 0 \\
        --out SOAK_report.json
    python -m repro.chaos.soak --profile long --duration 3600

Each soak = (optional) one CLEAN run, then one or more CHAOS runs, each
a full ``AsyncTrainer(mode="procs")`` training on Pendulum with a
seeded :class:`~repro.chaos.faults.FaultPlan` injected through the
supervisor seam while an :class:`~repro.chaos.monitor.InvariantMonitor`
checks every PR 1-6 invariant live. After EVERY run (clean and chaotic
alike) the :class:`~repro.chaos.audit.ResourceAuditor` diffs
``/dev/shm``, parent fds, and child pids against the pre-soak baseline
— one leaked resource fails the soak.

``--duration S`` keeps launching chaos runs (seed, seed+1000, ...)
until S seconds have elapsed — the scheduled-job long soak. Without it,
exactly one chaos run executes — the PR-CI short soak.

The report (``SOAK_report.json``) is machine-readable; exit status is 0
only when every run had ZERO violations, ZERO leaks, and the first
chaos run injected at least the profile's ``min_faults`` spanning all
three role families. A watchdog hard-kills a wedged run (exit 70) so a
hung soak can never hang CI.

Collection is paced (``pace_collection`` + ``collect_speed``) so
progress — the fault schedule's clock — advances over real seconds
instead of leaping queue-burst to queue-burst; without pacing a
simulated Pendulum fleet can blow through the whole fault window
between two supervisor ticks.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time
from typing import Any, Dict, Optional

PROFILES: Dict[str, Dict[str, Any]] = {
    # tests / absolute-smoke: a couple of faults, ~1-2 min
    "micro": dict(total_trajs=12, clean_trajs=0, n_collectors=2,
                  n_faults=5, min_faults=3, max_kills_per_role=2,
                  max_restarts=3, collect_speed=40.0,
                  hard_timeout_s=540.0),
    # PR CI (`make soak`): >= 10 faults across all roles, a few minutes
    "short": dict(total_trajs=40, clean_trajs=8, n_collectors=2,
                  n_faults=14, min_faults=10, max_kills_per_role=3,
                  max_restarts=4, collect_speed=20.0,
                  hard_timeout_s=1500.0),
    # scheduled job (`make soak-long`, optionally with --duration)
    "long": dict(total_trajs=120, clean_trajs=16, n_collectors=3,
                 n_faults=40, min_faults=30, max_kills_per_role=6,
                 max_restarts=8, collect_speed=10.0,
                 hard_timeout_s=7200.0),
}

_FAMILIES = ("collector", "model", "policy")


def _build(profile: Dict[str, Any], seed: int, total_trajs: int):
    """Env + configs + RunConfig for one soak run (mirrors the tiny
    shapes the procs tests train, so a soak compiles fast and exercises
    the same code paths CI already trusts)."""
    from repro.core import RunConfig
    from repro.envs import make_env
    from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig
    env = make_env("pendulum")
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=32, n_models=2)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=16)
    acfg = AlgoConfig(algo="me-trpo", imagine_batch=16,
                      imagine_horizon=15, n_models=2)
    rc = RunConfig(total_trajs=int(total_trajs), seed=int(seed),
                   n_collectors=int(profile["n_collectors"]),
                   max_restarts=int(profile["max_restarts"]),
                   collect_speed=float(profile["collect_speed"]),
                   pace_collection=True,
                   snapshot_every_s=0.5,
                   push_timeout_s=90.0,
                   eval_rollouts=2, eval_every_policy_steps=20,
                   min_final_model_version=1,
                   min_final_policy_version=1,
                   transport=str(profile.get("transport", "shm")))
    return env, ens, pol, acfg, rc


def _one_run(profile: Dict[str, Any], seed: int, *,
             chaos: bool, report: Dict[str, Any],
             out_path: Optional[str]) -> Dict[str, Any]:
    """Execute one training run (chaotic or clean) under a watchdog and
    return its report entry."""
    from repro.chaos.faults import ChaosSupervisor, FaultPlan
    from repro.chaos.monitor import InvariantMonitor
    from repro.core import AsyncTrainer, SupervisorChain
    trajs = profile["total_trajs"] if chaos else profile["clean_trajs"]
    env, ens, pol, acfg, rc = _build(profile, seed, trajs)
    monitor = InvariantMonitor()
    sups = [monitor]
    injector = None
    if chaos:
        plan = FaultPlan.generate(
            seed, n_collectors=rc.n_collectors,
            n_faults=int(profile["n_faults"]),
            max_kills_per_role=int(profile["max_kills_per_role"]))
        injector = ChaosSupervisor(plan)
        sups.insert(0, injector)
    tr = AsyncTrainer(env, ens, None, rc, mode="procs",
                      algo_cfg=acfg, pol_cfg=pol,
                      supervisor=SupervisorChain(*sups))

    done = threading.Event()

    def watchdog():
        if done.wait(float(profile["hard_timeout_s"])):
            return
        report["aborted"] = (f"watchdog: run (seed={seed}, "
                             f"chaos={chaos}) exceeded hard timeout "
                             f"{profile['hard_timeout_s']}s")
        if out_path:
            _write_report(report, out_path)
        for p in getattr(tr, "_procs", {}).values():   # unhang CI
            try:
                p.kill()
            except Exception:
                pass
        os._exit(70)

    threading.Thread(target=watchdog, daemon=True).start()
    t0 = time.monotonic()
    error = None
    try:
        trace = tr.run()
    except Exception as e:      # noqa: BLE001 — soak must report, not die
        trace = []
        error = f"{type(e).__name__}: {e}"
    finally:
        done.set()
    entry: Dict[str, Any] = {
        "kind": "chaos" if chaos else "clean",
        "seed": int(seed),
        "wall_s": round(time.monotonic() - t0, 2),
        "error": error,
        "trace_rows": len(trace),
        "trajs": tr.proc_info.get("trajs"),
        "model_version": tr.proc_info.get("model_version"),
        "policy_version": tr.proc_info.get("policy_version"),
        "restarts": {k: int(v)
                     for k, v in tr.proc_info["restarts"].items()},
        "monitor": monitor.report(),
    }
    if injector is not None:
        entry["faults"] = injector.report()
    return entry


def _write_report(report: Dict[str, Any], out_path: str) -> None:
    tmp = out_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    os.replace(tmp, out_path)


def run_soak(profile_name: str = "short", seed: int = 0, *,
             duration: Optional[float] = None,
             out: str = "SOAK_report.json",
             overrides: Optional[Dict[str, Any]] = None) -> int:
    """Run the soak; write ``out``; return the process exit code."""
    from repro.chaos.audit import ResourceAuditor
    from repro.chaos.faults import role_family
    profile = dict(PROFILES[profile_name])
    profile.update(overrides or {})
    report: Dict[str, Any] = {
        "profile": profile_name, "seed": int(seed),
        "config": {k: v for k, v in profile.items()},
        "started_unix": time.time(), "runs": [], "aborted": None,
    }
    # warm the parent's lazy allocations (jax client, multiprocessing's
    # shared-heap arena + resource tracker) BEFORE the leak baseline, so
    # process-lifetime fds are in it and only per-run leaks show in the
    # diff
    import jax
    import jax.numpy as jnp
    from repro.chaos.audit import warmup_ipc
    jnp.zeros(()).block_until_ready()
    jax.devices()
    warmup_ipc()
    auditor = ResourceAuditor()
    auditor.baseline()

    t_start = time.monotonic()
    if profile["clean_trajs"]:
        entry = _one_run(profile, seed, chaos=False, report=report,
                         out_path=out)
        entry["audit"] = auditor.audit()
        report["runs"].append(entry)
        _write_report(report, out)
    run_i = 0
    while True:
        entry = _one_run(profile, seed + 1000 * run_i, chaos=True,
                         report=report, out_path=out)
        entry["audit"] = auditor.audit()
        report["runs"].append(entry)
        _write_report(report, out)
        run_i += 1
        elapsed = time.monotonic() - t_start
        if duration is None or elapsed >= float(duration):
            break

    # ------------------------------------------------- verdict
    chaos_runs = [r for r in report["runs"] if r["kind"] == "chaos"]
    first = chaos_runs[0]
    injected = first.get("faults", {}).get("injected", [])
    families = sorted({role_family(f["role"]) for f in injected})
    problems = []
    for r in report["runs"]:
        tag = f"{r['kind']} run seed={r['seed']}"
        if r["error"]:
            problems.append(f"{tag}: {r['error']}")
        problems += [f"{tag}: {v}"
                     for v in r["monitor"]["violations"]]
        if not r["audit"]["ok"]:
            problems.append(f"{tag}: resource leak {r['audit']}")
    if len(injected) < int(profile["min_faults"]):
        problems.append(
            f"only {len(injected)} faults injected, profile requires >= "
            f"{profile['min_faults']}")
    if families != sorted(_FAMILIES):
        problems.append(
            f"faults only hit {families}, need all of {_FAMILIES}")
    report.update({
        "wall_s": round(time.monotonic() - t_start, 2),
        "totals": {
            "runs": len(report["runs"]),
            "faults_injected": sum(
                len(r.get("faults", {}).get("injected", []))
                for r in chaos_runs),
            "families_first_run": families,
            "restarts": sum(sum(r["restarts"].values())
                            for r in report["runs"]),
        },
        "required": {"min_faults": int(profile["min_faults"]),
                     "families": list(_FAMILIES)},
        "problems": problems,
        "ok": not problems,
    })
    _write_report(report, out)
    status = "OK" if report["ok"] else "FAIL"
    print(f"soak {status}: {report['totals']['faults_injected']} faults "
          f"over {len(report['runs'])} run(s) in {report['wall_s']}s "
          f"-> {out}")
    for p in problems:
        print(f"  problem: {p}")
    return 0 if report["ok"] else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.chaos.soak", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--profile", choices=sorted(PROFILES), default="short")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--duration", type=float, default=None,
                    help="keep launching chaos runs until this many "
                         "seconds have elapsed (default: one run)")
    ap.add_argument("--out", default="SOAK_report.json")
    ap.add_argument("--trajs", type=int, default=None,
                    help="override the profile's total_trajs")
    ap.add_argument("--faults", type=int, default=None,
                    help="override the profile's planned fault count")
    ap.add_argument("--transport", choices=["shm", "tcp"], default="shm",
                    help="server transport under chaos: shm (default) "
                         "or the tcp control plane — SIGKILLed remote "
                         "collectors must refund exactly and the "
                         "monitor must see the same invariants")
    args = ap.parse_args(argv)
    overrides: Dict[str, Any] = {"transport": args.transport}
    if args.trajs is not None:
        overrides["total_trajs"] = args.trajs
    if args.faults is not None:
        overrides["n_faults"] = args.faults
    return run_soak(args.profile, args.seed, duration=args.duration,
                    out=args.out, overrides=overrides)


if __name__ == "__main__":
    sys.exit(main())
