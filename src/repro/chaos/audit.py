"""Resource auditing: prove a procs run leaks NOTHING (PR 7).

A crashed child, a torn-down queue, or a GC-order-dependent ``close``
can each strand a kernel resource that survives the run: a named shm
segment in ``/dev/shm``, a pipe/socket fd in the parent, an orphaned
child process. Over an hours-long soak those leaks compound into ENOSPC
/ EMFILE / pid exhaustion — so the soak harness treats ONE leaked
resource as a failure.

:class:`ResourceAuditor` snapshots the parent's observable resources
before the run (``baseline()``) and diffs after (``audit()``):

* ``/dev/shm`` entries (named segments: ``ShmParameterServer`` payloads
  and anything else a run creates);
* ``/proc/self/fd`` targets, filtered to leakable kinds (``pipe:``,
  ``socket:``, ``/dev/shm/...``, memfds) — kernel object ids are
  unique, so a NEW pipe id still present after teardown is a leak even
  if the fd number was reused;
* direct children from ``/proc/*/stat`` ppid scans, excluding
  multiprocessing's long-lived ``resource_tracker`` (it legitimately
  persists for the parent's lifetime);
* the in-process audit registries
  (``servers.live_shm_segments`` / ``servers.live_data_servers``) — a
  server constructed but never closed is a leak even before the kernel
  notices.

``audit(settle_s=...)`` polls until clean or the settle window expires:
queue feeder threads and the resource tracker unlink asynchronously, so
an immediate diff would flag transients.
"""
from __future__ import annotations

import gc
import os
import time
from typing import Any, Dict, Set

from repro.core.servers import live_data_servers, live_shm_segments

# fd targets that indicate an IPC resource we could have leaked; other
# kinds (files, ttys, eventfds jax opens lazily) are process-lifetime
# caches, not per-run leaks
_LEAKABLE_FD_PREFIXES = ("pipe:", "socket:", "/dev/shm", "/memfd:")


def _shm_entries() -> Set[str]:
    try:
        return set(os.listdir("/dev/shm"))
    except OSError:
        return set()


def _fd_targets() -> Dict[int, str]:
    out: Dict[int, str] = {}
    try:
        fds = os.listdir("/proc/self/fd")
    except OSError:
        return out
    for fd in fds:
        try:
            out[int(fd)] = os.readlink(f"/proc/self/fd/{fd}")
        except OSError:     # the listing fd itself, or a racing close
            pass
    return out


def _child_procs() -> Dict[int, str]:
    """pid -> cmdline for every direct child of this process."""
    me = os.getpid()
    out: Dict[int, str] = {}
    try:
        entries = os.listdir("/proc")
    except OSError:
        return out
    for name in entries:
        if not name.isdigit():
            continue
        try:
            with open(f"/proc/{name}/stat") as f:
                stat = f.read()
            # field 4 is ppid; comm (field 2) may contain spaces, so
            # split AFTER the closing paren
            ppid = int(stat.rsplit(")", 1)[1].split()[1])
        except (OSError, ValueError, IndexError):
            continue
        if ppid != me:
            continue
        try:
            with open(f"/proc/{name}/cmdline") as f:
                cmd = f.read().replace("\0", " ").strip()
        except OSError:
            cmd = "?"
        out[int(name)] = cmd
    return out


def _is_tracker(cmd: str) -> bool:
    return "resource_tracker" in cmd or "semaphore_tracker" in cmd


def warmup_ipc() -> None:
    """Force multiprocessing's lazy PROCESS-LIFETIME allocations — the
    shared-heap arena mmap backing ``Value``/``Array`` (two fds on
    ``/dev/shm/pym-*``) and the resource-tracker child plus its pipe —
    so they exist before ``baseline()`` and never read as run leaks.
    Idempotent, cheap, spawns no worker."""
    import multiprocessing as mp
    ctx = mp.get_context("spawn")
    v = ctx.Value("q", 0)
    a = ctx.Array("d", 2, lock=False)
    ev = ctx.Event()
    q = ctx.Queue()
    q.close()
    q.join_thread()
    del v, a, ev, q
    gc.collect()


class ResourceAuditor:
    def __init__(self):
        self.before: Dict[str, Any] = {}

    @staticmethod
    def snapshot() -> Dict[str, Any]:
        return {"shm": _shm_entries(), "fds": _fd_targets(),
                "children": _child_procs()}

    def baseline(self) -> Dict[str, Any]:
        """Take the pre-run snapshot. Call AFTER jax and multiprocessing
        have warmed up (first device op, first spawned child) so their
        lazily-opened process-lifetime fds don't read as run leaks."""
        self.before = self.snapshot()
        return self.before

    def audit(self, *, settle_s: float = 3.0) -> Dict[str, Any]:
        """Diff now against the baseline; re-check until clean or the
        settle window expires (feeder threads / the resource tracker
        reclaim asynchronously after close)."""
        assert self.before, "call baseline() before audit()"
        deadline = time.monotonic() + float(settle_s)
        while True:
            # sweep harness-side reference cycles first: an mp lock or
            # queue kept alive only by an uncollected cycle is pending
            # reclamation, not leaked
            gc.collect()
            report = self._diff(self.snapshot())
            if report["ok"] or time.monotonic() >= deadline:
                return report
            time.sleep(0.1)

    def _diff(self, after: Dict[str, Any]) -> Dict[str, Any]:
        b = self.before
        leaked_shm = sorted(after["shm"] - b["shm"])
        before_targets = set(b["fds"].values())
        leaked_fds = sorted(
            f"fd {fd} -> {tgt}" for fd, tgt in after["fds"].items()
            if tgt not in before_targets
            and tgt.startswith(_LEAKABLE_FD_PREFIXES))
        leaked_children = {
            str(pid): cmd for pid, cmd in after["children"].items()
            if pid not in b["children"] and not _is_tracker(cmd)}
        registries = {"shm_segments": list(live_shm_segments()),
                      "data_servers": int(live_data_servers())}
        ok = not (leaked_shm or leaked_fds or leaked_children
                  or registries["shm_segments"]
                  or registries["data_servers"])
        return {"ok": ok, "leaked_shm": leaked_shm,
                "leaked_fds": leaked_fds,
                "leaked_children": leaked_children,
                "registries": registries}
