"""Always-on invariant monitoring DURING a procs-mode run (PR 7).

Every invariant the repo's tests check post-hoc is verified here while
the run is live, from the parent's supervision loop, via the
:class:`repro.core.runtime.Supervisor` seam:

* **Exact criterion** — ``total_pushed`` never exceeds ``total_trajs``
  mid-run (refund accounting can't overshoot), and lands EXACTLY on it
  at completion (crash refunds can't undershoot).
* **Monotone versions** — the shm version words of both parameter
  stores only ever increase, including across child crash-restarts
  (the version word lives IN shm precisely so a restarted writer
  continues the sequence instead of resetting it).
* **Zero retraces after warmup** — children publish their jit
  compile counts through the heartbeat array
  (``workers.compile_count`` / ``utils.jit_stats``); each role has a
  hard per-process cap (model 1, policy 1, collector 1 — or 2 with an
  env farm, whose final partial grant may touch the single-rollout
  program). Exceeding the cap means the hot path retraced.
* **Bounded restarts** — per-role crash counts never exceed
  ``max_restarts`` (the supervisor raises at >, so observing it here
  means the budget check itself broke).

Violations accumulate as strings in ``.violations`` — empty at the end
of a chaotic run is the soak harness's core pass criterion.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List

from repro.core.runtime import Supervisor
from repro.core.workers import heartbeat_slot


class InvariantMonitor(Supervisor):
    def __init__(self, *, check_every_s: float = 0.05):
        self.check_every_s = float(check_every_s)
        self.violations: List[str] = []
        self.stats: Dict[str, Any] = {}

    def attach(self, trainer) -> None:
        super().attach(trainer)
        rc = trainer.run_cfg
        self._collector_cap = 1 if rc.envs_per_collector == 1 else 2
        self._seen_versions = {"model": 0, "policy": 0}
        self._next_check = 0.0
        self.stats = {"ticks": 0, "checks": 0, "child_exits": [],
                      "max_compiles": {}, "max_versions": {},
                      "final": {}}

    # ----------------------------------------------------------- seam
    def on_tick(self) -> None:
        self.stats["ticks"] += 1
        now = time.monotonic()
        if now < self._next_check:
            return
        self._next_check = now + self.check_every_s
        self.stats["checks"] += 1
        self._check_versions()
        self._check_criterion_bound()
        self._check_budgets()
        self._check_compiles()

    def on_child_exit(self, role, exitcode, n_restarts) -> None:
        self.stats["child_exits"].append(
            {"role": role, "exitcode": int(exitcode),
             "n_restarts": int(n_restarts)})

    def on_complete(self) -> None:
        """Completion-time checks: the criterion must land EXACTLY (the
        refund accounting's whole point) and nothing may still be in
        flight."""
        tr = self.trainer
        rc = tr.run_cfg
        data = tr._proc_servers["data"]
        pushed = data.total_pushed
        if pushed != rc.total_trajs:
            self._violate(
                f"criterion missed: run completed with total_pushed="
                f"{pushed}, expected exactly {rc.total_trajs}")
        self._check_versions()
        self._check_compiles()
        self.stats["final"] = {
            "total_pushed": int(pushed),
            "model_version": int(tr._proc_servers["model"].version),
            "policy_version": int(tr._proc_servers["policy"].version),
            "restarts": dict(tr.proc_info["restarts"])}

    # -------------------------------------------------------- checks
    def _violate(self, msg: str) -> None:
        if msg not in self.violations:
            self.violations.append(msg)

    def _check_versions(self) -> None:
        srv = self.trainer._proc_servers
        for name in ("model", "policy"):
            v = int(srv[name].version)
            seen = self._seen_versions[name]
            if v < seen:
                self._violate(
                    f"{name} version went BACKWARDS: {seen} -> {v} "
                    "(restart must republish at a version >= the "
                    "snapshot's, never reset the shm version word)")
            self._seen_versions[name] = max(v, seen)
            self.stats["max_versions"][name] = self._seen_versions[name]

    def _check_criterion_bound(self) -> None:
        tr = self.trainer
        pushed = tr._proc_servers["data"].total_pushed
        if pushed > tr.run_cfg.total_trajs:
            self._violate(
                f"criterion OVERSHOT mid-run: total_pushed={pushed} > "
                f"total_trajs={tr.run_cfg.total_trajs} (ticket claims / "
                "crash refunds let extra trajectories through)")

    def _check_budgets(self) -> None:
        rc = self.trainer.run_cfg
        for role, n in self.trainer.proc_info["restarts"].items():
            if n > rc.max_restarts:
                self._violate(
                    f"restart budget exceeded silently: {role} at "
                    f"{n} > max_restarts={rc.max_restarts} without the "
                    "supervisor failing the run")

    def _check_compiles(self) -> None:
        tr = self.trainer
        rc = tr.run_cfg
        ch = tr._proc_channels
        for role in tr.proc_info["restarts"]:
            cap = (self._collector_cap if role.startswith("collector")
                   else 1)
            slot = heartbeat_slot(role, rc.n_collectors)
            _beat, compiles = ch.read_heartbeat(slot)
            c = int(compiles)
            if c < 0:
                continue    # jax hid the cache: unknown, not a violation
            seen = self.stats["max_compiles"].get(role, 0)
            self.stats["max_compiles"][role] = max(seen, c)
            if c > cap:
                self._violate(
                    f"{role} RETRACED after warmup: compile count {c} > "
                    f"cap {cap} (PR 1 compile-once invariant broken in "
                    "the child's hot path)")

    # ---------------------------------------------------------- report
    def report(self) -> Dict[str, Any]:
        return {"violations": list(self.violations),
                "stats": dict(self.stats)}
