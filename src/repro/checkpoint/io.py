"""Checkpointing: flat-key .npz pytree snapshots (no orbax offline).

Layout: <dir>/step_<N>/arrays.npz + tree.json (structure + dtypes).
Works for params, optimizer states, MBRL worker states — anything made of
array leaves. Keeps the last ``keep`` steps.

Crash-atomic (chaos invariant, PR 7): every snapshot is written to a
``.tmp`` sibling first — file contents flushed AND fsynced, then the
directory atomically renamed over the target, then the parent directory
fsynced — so a writer SIGKILLed at ANY instruction can only ever leave
(a) the previous complete snapshot plus (b) an ignorable ``.tmp``
leftover. ``latest_step``/``restore`` only accept exact ``step_<N>``
names, and ``restore`` falls back to the NEWEST snapshot that actually
loads, skipping truncated/corrupt ones — a supervisor killed
mid-snapshot can never poison a restart.

The flat-key codec (flatten -> per-leaf storable dtype view -> restore)
is exposed as ``flat_codec`` so other fixed-structure array transports
can share it — the process-isolated engine's shared-memory parameter
store (core/servers.ShmParameterServer) serialises every push/pull with
it instead of pickling pytrees.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from pathlib import Path
from typing import List, Optional

import jax
import ml_dtypes
import numpy as np

_SEP = "/"
_STEP_RE = re.compile(r"step_(\d+)$")

# numpy's savez can't round-trip ml_dtypes (bfloat16 etc.); store them as
# same-width unsigned ints and view back on load.
_EXOTIC = {np.dtype(ml_dtypes.bfloat16): np.uint16,
           np.dtype(ml_dtypes.float8_e4m3fn): np.uint8}


def _to_storable(a):
    a = np.asarray(a)
    if a.dtype in _EXOTIC:
        return a.view(_EXOTIC[a.dtype])
    return a


def _from_storable(a, dtype):
    dt = np.dtype(dtype)
    if dt in _EXOTIC:
        return a.view(dt)
    return a.astype(dt)


def _flatten(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


class LeafCodec:
    """Flat-key codec for ONE pytree structure: host-materialises leaves
    into their storable (npz/shm-safe) dtypes and restores them. The
    structure, shapes and dtypes are fixed at construction from a
    template, so encode/decode never re-derive metadata — exactly what a
    preallocated shared-memory transport needs."""

    def __init__(self, template):
        flat, self.treedef = _flatten(template)
        self.dtypes = [np.dtype(getattr(x, "dtype", None)
                                or np.asarray(x).dtype) for x in flat]
        self.shapes = [tuple(x.shape) for x in flat]
        self.storable_dtypes = [_EXOTIC.get(dt, dt) for dt in self.dtypes]
        self.nbytes = [int(np.prod(s, dtype=np.int64)) * np.dtype(sd).itemsize
                       for s, sd in zip(self.shapes, self.storable_dtypes)]

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    def encode(self, tree):
        """Pytree -> list of host np arrays in storable dtypes (the only
        device->host hop of a cross-process push)."""
        flat, treedef = _flatten(tree)
        assert treedef == self.treedef, (treedef, self.treedef)
        return [np.ascontiguousarray(_to_storable(np.asarray(x)))
                for x in flat]

    def decode(self, flat_storable):
        """List of storable np arrays -> pytree with original dtypes."""
        leaves = [_from_storable(a, dt).reshape(s) for a, dt, s in
                  zip(flat_storable, self.dtypes, self.shapes)]
        return jax.tree.unflatten(self.treedef, leaves)


def _fsync_file(f) -> None:
    f.flush()
    os.fsync(f.fileno())


def _fsync_dir(path) -> None:
    # a rename is only durable once the containing directory's entry is
    # on disk; some filesystems reject O_RDONLY fsync — best effort there
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save_pytree(path, tree, *, step: Optional[int] = None, keep: int = 3):
    """Save under path/step_<N> (or path directly if step is None).

    Crash-atomic: contents land in ``<target>.tmp`` (each file flushed +
    fsynced), the tmp dir is renamed over the target in one atomic
    ``os.replace``, and the parent directory is fsynced — a writer
    killed mid-snapshot leaves only an ignorable ``.tmp`` leftover,
    never a truncated ``step_<N>``. Stale ``.tmp`` leftovers from
    previous crashes are swept on the next save."""
    base = Path(path)
    target = base / f"step_{step:09d}" if step is not None else base
    tmp = target.with_name(target.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, treedef = _flatten(tree)
    arrays = {f"a{i}": _to_storable(x) for i, x in enumerate(flat)}
    with open(tmp / "arrays.npz", "wb") as f:
        np.savez(f, **arrays)
        _fsync_file(f)
    with open(tmp / "tree.json", "w") as f:
        f.write(json.dumps({
            "treedef": str(treedef),
            "n": len(flat),
            "dtypes": [str(np.asarray(x).dtype) for x in flat],
            "shapes": [list(np.asarray(x).shape) for x in flat],
        }))
        _fsync_file(f)
    if target.exists():
        shutil.rmtree(target)
    os.replace(tmp, target)
    _fsync_dir(target.parent)
    if step is not None and keep:
        for old in _step_dirs(base)[:-keep]:
            shutil.rmtree(base / f"step_{old:09d}")
        # crashed writers leave orphaned .tmp dirs; sweep any that are
        # not the snapshot we just renamed away
        for leftover in base.glob("step_*.tmp"):
            if leftover.is_dir():
                shutil.rmtree(leftover, ignore_errors=True)
    return target


def load_pytree(path, like):
    """Load into the structure of ``like`` (a pytree template)."""
    target = Path(path)
    data = np.load(target / "arrays.npz")
    flat_like, treedef = _flatten(like)
    meta = json.loads((target / "tree.json").read_text())
    assert meta["n"] == len(flat_like), \
        f"checkpoint has {meta['n']} leaves, template has {len(flat_like)}"
    flat = [data[f"a{i}"] for i in range(meta["n"])]
    out = []
    for i, (x, tmpl) in enumerate(zip(flat, flat_like)):
        arr = np.asarray(x)
        t = np.asarray(tmpl) if not hasattr(tmpl, "dtype") else tmpl
        assert arr.shape == tuple(t.shape), (arr.shape, t.shape)
        out.append(_from_storable(arr, meta["dtypes"][i]))
    return jax.tree.unflatten(treedef, out)


def _step_dirs(base: Path) -> List[int]:
    """Step numbers of EXACT ``step_<N>`` directories, ascending.
    ``.tmp`` leftovers and other stragglers never match (a leftover
    ``step_000000002.tmp`` used to crash the int parse here)."""
    steps = []
    for p in base.glob("step_*"):
        m = _STEP_RE.fullmatch(p.name)
        if m and p.is_dir():
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(path) -> Optional[int]:
    steps = _step_dirs(Path(path))
    return steps[-1] if steps else None


def restore(path, like):
    """Load the newest step_<N> under path (or path itself).

    Robust to a supervisor killed mid-snapshot: candidate steps are
    tried NEWEST FIRST and any that fail to load (truncated arrays.npz,
    missing/garbled tree.json — only possible for snapshots written by
    pre-atomic writers or torn by the filesystem) are skipped, so a
    restart lands on the latest COMPLETE checkpoint instead of dying on
    a corrupt one. Raises only when no complete snapshot exists at all.
    """
    base = Path(path)
    steps = _step_dirs(base)
    if not steps:
        return load_pytree(base, like), None
    last_err: Optional[Exception] = None
    for step in reversed(steps):
        try:
            return load_pytree(base / f"step_{step:09d}", like), step
        except Exception as e:        # truncated/corrupt: try the older one
            last_err = e
    raise FileNotFoundError(
        f"no complete checkpoint under {base} "
        f"(all of steps {steps} failed to load)") from last_err
