"""Checkpointing: flat-key .npz pytree snapshots (no orbax offline).

Layout: <dir>/step_<N>/arrays.npz + tree.json (structure + dtypes).
Works for params, optimizer states, MBRL worker states — anything made of
array leaves. Atomic via tmp-dir rename; keeps the last ``keep`` steps.

The flat-key codec (flatten -> per-leaf storable dtype view -> restore)
is exposed as ``flat_codec`` so other fixed-structure array transports
can share it — the process-isolated engine's shared-memory parameter
store (core/servers.ShmParameterServer) serialises every push/pull with
it instead of pickling pytrees.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Optional

import jax
import ml_dtypes
import numpy as np

_SEP = "/"

# numpy's savez can't round-trip ml_dtypes (bfloat16 etc.); store them as
# same-width unsigned ints and view back on load.
_EXOTIC = {np.dtype(ml_dtypes.bfloat16): np.uint16,
           np.dtype(ml_dtypes.float8_e4m3fn): np.uint8}


def _to_storable(a):
    a = np.asarray(a)
    if a.dtype in _EXOTIC:
        return a.view(_EXOTIC[a.dtype])
    return a


def _from_storable(a, dtype):
    dt = np.dtype(dtype)
    if dt in _EXOTIC:
        return a.view(dt)
    return a.astype(dt)


def _flatten(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


class LeafCodec:
    """Flat-key codec for ONE pytree structure: host-materialises leaves
    into their storable (npz/shm-safe) dtypes and restores them. The
    structure, shapes and dtypes are fixed at construction from a
    template, so encode/decode never re-derive metadata — exactly what a
    preallocated shared-memory transport needs."""

    def __init__(self, template):
        flat, self.treedef = _flatten(template)
        self.dtypes = [np.dtype(getattr(x, "dtype", None)
                                or np.asarray(x).dtype) for x in flat]
        self.shapes = [tuple(x.shape) for x in flat]
        self.storable_dtypes = [_EXOTIC.get(dt, dt) for dt in self.dtypes]
        self.nbytes = [int(np.prod(s, dtype=np.int64)) * np.dtype(sd).itemsize
                       for s, sd in zip(self.shapes, self.storable_dtypes)]

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    def encode(self, tree):
        """Pytree -> list of host np arrays in storable dtypes (the only
        device->host hop of a cross-process push)."""
        flat, treedef = _flatten(tree)
        assert treedef == self.treedef, (treedef, self.treedef)
        return [np.ascontiguousarray(_to_storable(np.asarray(x)))
                for x in flat]

    def decode(self, flat_storable):
        """List of storable np arrays -> pytree with original dtypes."""
        leaves = [_from_storable(a, dt).reshape(s) for a, dt, s in
                  zip(flat_storable, self.dtypes, self.shapes)]
        return jax.tree.unflatten(self.treedef, leaves)


def save_pytree(path, tree, *, step: Optional[int] = None, keep: int = 3):
    """Save under path/step_<N> (or path directly if step is None)."""
    base = Path(path)
    target = base / f"step_{step:09d}" if step is not None else base
    tmp = target.with_name(target.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, treedef = _flatten(tree)
    arrays = {f"a{i}": _to_storable(x) for i, x in enumerate(flat)}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "tree.json").write_text(json.dumps({
        "treedef": str(treedef),
        "n": len(flat),
        "dtypes": [str(np.asarray(x).dtype) for x in flat],
        "shapes": [list(np.asarray(x).shape) for x in flat],
    }))
    if target.exists():
        shutil.rmtree(target)
    os.replace(tmp, target)
    if step is not None and keep:
        steps = sorted(p for p in base.glob("step_*") if p.is_dir())
        for old in steps[:-keep]:
            shutil.rmtree(old)
    return target


def load_pytree(path, like):
    """Load into the structure of ``like`` (a pytree template)."""
    target = Path(path)
    data = np.load(target / "arrays.npz")
    flat_like, treedef = _flatten(like)
    meta = json.loads((target / "tree.json").read_text())
    assert meta["n"] == len(flat_like), \
        f"checkpoint has {meta['n']} leaves, template has {len(flat_like)}"
    flat = [data[f"a{i}"] for i in range(meta["n"])]
    out = []
    for i, (x, tmpl) in enumerate(zip(flat, flat_like)):
        arr = np.asarray(x)
        t = np.asarray(tmpl) if not hasattr(tmpl, "dtype") else tmpl
        assert arr.shape == tuple(t.shape), (arr.shape, t.shape)
        out.append(_from_storable(arr, meta["dtypes"][i]))
    return jax.tree.unflatten(treedef, out)


def latest_step(path) -> Optional[int]:
    base = Path(path)
    steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*")
                   if p.is_dir())
    return steps[-1] if steps else None


def restore(path, like):
    """Load the newest step_<N> under path (or path itself)."""
    step = latest_step(path)
    target = Path(path) / f"step_{step:09d}" if step is not None else path
    return load_pytree(target, like), step
