"""Data pipeline for world-model pre-training.

Two sources:

* ``DynamicsTokenStream`` — deterministic synthetic 'tokenised dynamics'
  (s_{t+1} = f(s_t, a_t) mod V): an infinite, seekable stream used by the
  training examples and perf tests. Deterministic per (seed, step) so a
  restored checkpoint resumes on identical data.
* ``trajectory_tokens`` — discretises real MBRL trajectories (obs/act from
  the replay buffer) into world-model token sequences via per-dimension
  uniform binning, the bridge between the paper's replay buffer and the
  transformer world models.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DynamicsTokenStream:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int):
        """Batch for global step ``step`` (pure function of (seed, step))."""
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k1, k2 = jax.random.split(key)
        s0 = jax.random.randint(k1, (self.batch,), 0, self.vocab)
        acts = jax.random.randint(k2, (self.batch, self.seq_len), 0, 7)

        def step_fn(s, a):
            s2 = (s * 31 + a * 131 + 17) % self.vocab
            return s2, s2

        _, toks = jax.lax.scan(step_fn, s0, jnp.swapaxes(acts, 0, 1))
        toks = jnp.swapaxes(toks, 0, 1).astype(jnp.int32)
        return {"tokens": toks, "labels": toks}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def trajectory_tokens(obs, act, *, bins: int = 32, obs_low=None,
                      obs_high=None):
    """Discretise (H, obs_dim) observations + (H, act_dim) actions into a
    single interleaved token sequence: per timestep,
    [obs_dim tokens][act_dim tokens]. Token ids are offset per dimension so
    the vocabulary factorises: vocab = bins * (obs_dim + act_dim)."""
    obs = jnp.asarray(obs)
    act = jnp.asarray(act)
    H, D = obs.shape
    A = act.shape[1]
    lo = jnp.asarray(obs_low) if obs_low is not None else obs.min(0)
    hi = jnp.asarray(obs_high) if obs_high is not None else obs.max(0)
    obs_bin = jnp.clip(((obs - lo) / jnp.maximum(hi - lo, 1e-6)
                        * (bins - 1)).astype(jnp.int32), 0, bins - 1)
    act_bin = jnp.clip(((jnp.clip(act, -1, 1) + 1) / 2
                        * (bins - 1)).astype(jnp.int32), 0, bins - 1)
    obs_tok = obs_bin + (jnp.arange(D) * bins)[None, :]
    act_tok = act_bin + ((D + jnp.arange(A)) * bins)[None, :]
    return jnp.concatenate([obs_tok, act_tok], axis=1).reshape(-1)
