from repro.data.synthetic import DynamicsTokenStream, trajectory_tokens
