"""Unified launcher.

Two entry modes:

* ``--task mbrl`` (the paper): asynchronous model-based RL on a pure-JAX
  env with ME-TRPO / ME-PPO / MB-MPO, async or sequential engines.

      python -m repro.launch.train --task mbrl --env pendulum \
          --algo me-trpo --engine async --trajs 60

* ``--task lm``: world-model / LM pre-training step loop for any assigned
  architecture (reduced configs run on CPU; full configs expect a pod).

      python -m repro.launch.train --task lm --arch glm4-9b --reduced \
          --steps 20 --seq 128 --batch 8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp


def build_mesh(spec: str):
    """``--mesh`` -> Mesh: "none" (single-device), "auto" (all local
    devices on one ("data",) axis), or an explicit device count "8"
    (errors if unavailable — combine with
    XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU)."""
    if spec == "none":
        return None
    n = jax.device_count() if spec == "auto" else int(spec)
    return jax.make_mesh((n,), ("data",))


def run_mbrl(args):
    from repro.core import (AsyncTrainer, PartialAsyncDataPolicy,
                            PartialAsyncModelPolicy, RunConfig,
                            SequentialTrainer)
    from repro.envs import make_env
    from repro.mbrl import (AlgoConfig, EnsembleConfig, PolicyConfig,
                            make_algo)

    mesh = build_mesh(args.mesh)
    role_ratios = tuple(int(x) for x in args.role_ratios.split(","))
    if mesh is not None and args.engine != "async":
        raise SystemExit("--mesh is only supported by --engine async "
                         "(role meshes belong to the async engine)")
    env = make_env(args.env)
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=args.model_hidden,
                         n_models=args.n_models)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=args.policy_hidden)
    acfg = AlgoConfig(algo=args.algo, imagine_batch=args.imagine_batch,
                      imagine_horizon=args.imagine_horizon,
                      n_models=args.n_models)
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
    collect_noise = (tuple(float(x) for x in args.collect_noise.split(","))
                     if args.collect_noise else None)
    rc = RunConfig(total_trajs=args.trajs, seed=args.seed,
                   collect_speed=args.collect_speed,
                   ema_weight=args.ema_weight,
                   early_stop=not args.no_early_stop,
                   ckpt_dir=args.ckpt_dir,
                   n_collectors=args.n_collectors,
                   collect_noise=collect_noise,
                   envs_per_collector=args.envs_per_collector,
                   transport=args.transport, bind=args.bind)
    if args.transport == "tcp" and args.engine != "async":
        raise SystemExit("--transport tcp needs --engine async "
                         "(the control plane serves the async servers)")
    if args.n_collectors > 1 and args.engine != "async":
        raise SystemExit("--n-collectors > 1 needs --engine async "
                         "(collector fleets belong to the async engine)")
    if args.envs_per_collector > 1 and args.engine != "async":
        raise SystemExit("--envs-per-collector > 1 needs --engine async "
                         "(env farms belong to the async engine)")
    if args.mode == "procs" and args.engine != "async":
        raise SystemExit("--mode procs is only meaningful with "
                         "--engine async")
    engines = {
        # procs children rebuild the algo from plain configs, so the
        # async engine gets them alongside the built algo object
        "async": lambda: AsyncTrainer(env, ens, algo, rc, mode=args.mode,
                                      mesh=mesh, role_ratios=role_ratios,
                                      algo_cfg=acfg, pol_cfg=pol),
        "sequential": lambda: SequentialTrainer(env, ens, algo, rc),
        "partial-model": lambda: PartialAsyncModelPolicy(env, ens, algo, rc),
        "partial-data": lambda: PartialAsyncDataPolicy(env, ens, algo, rc),
    }
    tr = engines[args.engine]()
    t0 = time.perf_counter()  # monotonic: an NTP step must not skew this
    trace = tr.run()
    out = {"engine": args.engine, "algo": args.algo, "env": args.env,
           "real_seconds": round(time.perf_counter() - t0, 1),
           "trace": trace}
    if getattr(tr, "roles", None) is not None:
        out["roles"] = tr.roles.describe()
    if getattr(tr, "collectors", None) is not None:
        # fleet report: each member's exploration rung and — for the
        # in-process engines — its share of the global criterion (the
        # procs fleet lives in child processes; its counts are global
        # only, reported in the "procs" block below)
        n = tr.run_cfg.n_collectors
        out["fleet"] = {
            "n_collectors": n,
            "envs_per_collector": tr.run_cfg.envs_per_collector,
            "sim_robots": n * tr.run_cfg.envs_per_collector,
            "noise_scales": [tr.exploration.scale_for(i)
                             for i in range(n)],
        }
        if args.mode != "procs":
            out["fleet"]["trajs_per_collector"] = \
                [c.collected for c in tr.collectors]
    if getattr(tr, "proc_info", None):
        out["procs"] = tr.proc_info
    print(json.dumps(out["trace"][-1], indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
        print("wrote", args.out)
    return trace


def run_join(args):
    """``--connect host:port``: no training here — this process donates
    ``--n-collectors`` remote collectors to a live run's control plane
    and exits when the run's global criterion is fully claimed."""
    from repro.net import join_as_collectors
    t0 = time.perf_counter()
    n = join_as_collectors(args.connect, n_collectors=args.n_collectors)
    print(json.dumps({"connect": args.connect,
                      "n_collectors": args.n_collectors,
                      "trajs_contributed": n,
                      "real_seconds": round(time.perf_counter() - t0, 1)},
                     indent=1))
    return n


def run_lm(args):
    from repro.configs import get_config, registry
    from repro.launch.mesh import make_smoke_mesh
    from repro.models import api
    from repro.models.config import InputShape
    from repro.optim.optimizers import adam

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_smoke_mesh()
    shape = InputShape("cli", args.seq, args.batch, "train")
    bundle = api.build(cfg, mesh, shape)
    mod = api._mod(cfg)
    key = jax.random.key(args.seed)
    params = mod.init_params(cfg, bundle.ctx, key)
    opt = adam(cfg.lr)
    opt_state = opt.init(params)

    def batch_for(k):
        b = {"tokens": jax.random.randint(k, (args.batch, args.seq), 0,
                                          cfg.vocab_size)}
        b["labels"] = b["tokens"]
        if cfg.family == "encdec":
            b["enc_embeds"] = jax.random.normal(
                k, (args.batch, args.seq, cfg.d_model), jnp.bfloat16)
        if cfg.modality == "vision":
            b["patch_embeds"] = jax.random.normal(
                k, (args.batch, args.seq // 8, cfg.d_model), jnp.bfloat16)
        return b

    for step in range(args.steps):
        key, k = jax.random.split(key)
        params, opt_state, m = bundle.fn(params, opt_state, batch_for(k))
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['gnorm']):.3f}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=["mbrl", "lm"], default="mbrl")
    # mbrl
    ap.add_argument("--env", default="pendulum")
    ap.add_argument("--algo", default="me-trpo",
                    choices=["me-trpo", "me-ppo", "mb-mpo"])
    ap.add_argument("--engine", default="async",
                    choices=["async", "sequential", "partial-model",
                             "partial-data"])
    ap.add_argument("--mode", default="event",
                    choices=["event", "threads", "procs"],
                    help="async engine execution: simulated (event), "
                         "host threads, or separate OS processes with "
                         "shared-memory parameter stores (procs)")
    ap.add_argument("--trajs", type=int, default=40)
    ap.add_argument("--n-models", type=int, default=5)
    ap.add_argument("--model-hidden", type=int, default=128)
    ap.add_argument("--policy-hidden", type=int, default=64)
    ap.add_argument("--imagine-batch", type=int, default=64)
    ap.add_argument("--imagine-horizon", type=int, default=40)
    ap.add_argument("--collect-speed", type=float, default=1.0)
    ap.add_argument("--n-collectors", type=int, default=1,
                    help="size of the data-collection fleet (async "
                         "engine, all modes): N parallel collectors "
                         "share the one global --trajs criterion")
    ap.add_argument("--collect-noise", default=None,
                    help="comma-separated per-collector exploration "
                         "noise scales, cycled across the fleet "
                         "(default: 1.0 everywhere)")
    ap.add_argument("--envs-per-collector", type=int, default=1,
                    help="env farm (async engine, all modes): each "
                         "collector simulates B envs per step through "
                         "one vmapped rollout and pushes the whole "
                         "batch at once (1 = classic single-rollout "
                         "collector)")
    ap.add_argument("--ema-weight", type=float, default=0.9)
    ap.add_argument("--no-early-stop", action="store_true")
    ap.add_argument("--mesh", default="none",
                    help="none | auto | <device count>: role-shard the "
                         "async engine over a device mesh (core/roles.py)")
    ap.add_argument("--role-ratios", default="1,2,1",
                    help="collector,model,policy share of the mesh axis")
    ap.add_argument("--transport", default="shm", choices=["shm", "tcp"],
                    help="how workers reach the servers: shm = in-process"
                         " / shared-memory fast path (default); tcp = "
                         "socket control plane (net/), reachable from "
                         "other hosts via --bind")
    ap.add_argument("--bind", default=None,
                    help="tcp transport: HOST:PORT the control plane "
                         "listens on (default 127.0.0.1:<ephemeral>); "
                         "bind :PORT or 0.0.0.0:PORT to let remote "
                         "collectors --connect")
    ap.add_argument("--connect", default=None,
                    help="join a LIVE run as extra remote collectors "
                         "instead of training: HOST:PORT of its control "
                         "plane (pair with --n-collectors for fan-out). "
                         "Connect only to planes you trust — the join "
                         "ticket is a pickle (docs/WIRE_PROTOCOL.md)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="procs mode: where the supervisor snapshots "
                         "params+versions (default: fresh temp dir)")
    ap.add_argument("--out", default=None)
    # lm
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.task == "mbrl":
        if args.connect:
            run_join(args)
            return
        run_mbrl(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
