"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (TypeError, AttributeError):
        # older jax: no axis_types kwarg / no jax.sharding.AxisType
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_smoke_mesh():
    """1x1 mesh for CPU smoke tests (same code path, trivial collectives)."""
    return _make_mesh((1, 1), ("data", "model"))
