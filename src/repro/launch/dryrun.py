import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import/init (device count locks on first use).

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
combination against the production meshes, and record the roofline raw
terms (FLOPs, bytes, per-collective traffic) to JSON.

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

Nothing is ever allocated at full size: parameters, optimizer state,
batches and caches are ShapeDtypeStructs (jax.eval_shape), and
``jit(...).lower(...).compile()`` produces only the executable.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import registry
from repro.models import api
from repro.models.config import INPUT_SHAPES

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


# --------------------------------------------------------------------------
# HLO parsing: per-collective bytes, with while-loop trip-count credit

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[8,128]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict:
    """name -> list of instruction lines. Computation headers start at
    column 0 with '%name (' or 'ENTRY'."""
    comps = {}
    order = []
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(", line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            order.append(cur)
        elif line.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _computation_multipliers(hlo: str) -> dict:
    """Map computation-name -> effective execution count.

    For every `while` op: the trip count is the largest s32 constant in
    its condition computation (scan conditions are `i < N`). Nested loops
    multiply via fixpoint propagation from the enclosing computation."""
    comps = _split_computations(hlo)

    def cond_trip(cond_name):
        best = None
        for ln in comps.get(cond_name, []):
            for mc in re.finditer(r"s32\[\]\s+constant\((\d+)\)", ln):
                v = int(mc.group(1))
                best = v if best is None else max(best, v)
        return best if best else 1

    edges = []  # (parent_comp, body_comp, trip)
    for comp, lines in comps.items():
        for ln in lines:
            mw = re.search(r"condition=%([\w\.\-]+), body=%([\w\.\-]+)", ln)
            if mw:
                edges.append((comp, mw.group(2), cond_trip(mw.group(1))))

    mult = {c: 1 for c in comps}
    for _ in range(8):  # fixpoint over nesting depth
        changed = False
        for parent, body, trip in edges:
            new = mult.get(parent, 1) * trip
            if mult.get(body) != new:
                mult[body] = new
                changed = True
        if not changed:
            break
    return mult


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return m.group(1).count(",") + 1
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota tile format [n_groups, group_size]
        return int(m.group(2))
    return 1


def _ici_bytes(c: str, result_bytes: int, g: int) -> float:
    """Ring-model bytes actually moved per device by one collective."""
    if g <= 1:
        return 0.0
    if c == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if c == "all-gather":
        return result_bytes * (g - 1) / g
    if c == "reduce-scatter":                 # result is the scattered shard
        return result_bytes * (g - 1)
    if c == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)                # collective-permute


def collective_bytes(hlo: str) -> dict:
    """Per-collective traffic from optimized HLO: operand/result bytes AND
    ring-model ICI bytes (group-size aware), with while-loop trip scaling."""
    trips = _computation_multipliers(hlo)
    comps = _split_computations(hlo)
    out = {c: 0 for c in COLLECTIVES}
    out["_unscaled"] = 0
    ici = 0.0
    coll_re = re.compile(
        r"=\s*(\([^=]*?\)|\S+)\s+(" + "|".join(COLLECTIVES)
        + r")(?:-start)?\(")
    for comp, lines in comps.items():
        mult = trips.get(comp, 1)
        for line in lines:
            m = coll_re.search(line)
            if not m:
                continue
            nbytes = _shape_bytes(m.group(1))
            c = m.group(2)
            out[c] += nbytes * mult
            out["_unscaled"] += nbytes
            ici += _ici_bytes(c, nbytes, _group_size(line)) * mult
    out["total"] = sum(out[c] for c in COLLECTIVES)
    out["ici_bytes"] = int(ici)
    return out


# --------------------------------------------------------------------------


def param_count(cfg) -> int:
    from repro.models.config import ShardCtx
    mod = api._mod(cfg)
    ctx = ShardCtx()  # unsharded count
    abs_p = jax.eval_shape(lambda k: mod.init_params(cfg, ctx, k),
                           jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
    return sum(x.size for x in jax.tree.leaves(abs_p))


def active_param_count(cfg) -> int:
    """Params touched per token (MoE: top_k of num_experts experts)."""
    total = param_count(cfg)
    if cfg.family != "moe" or not cfg.num_experts:
        return total
    expert = cfg.num_layers * 3 * cfg.d_model * cfg.d_ff * cfg.num_experts
    return total - expert + expert * cfg.top_k // cfg.num_experts


OPTIMIZED_TRAIN = {  # §Perf hillclimb settings (see EXPERIMENTS.md)
    "qwen3_moe_235b_a22b": dict(microbatch_tokens=16384, remat_group=8,
                                save_collectives=True, zero1=True),
    "_default": dict(save_collectives=True, microbatch_tokens=4096,
                     zero1=True),
}


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               optimized: bool = False, verbose: bool = True) -> dict:
    from repro.launch.mesh import make_production_mesh
    arch_n = registry.normalize(arch)
    shape = INPUT_SHAPES[shape_name]
    long_ctx = shape_name == "long_500k"
    rec = {"arch": arch_n, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    if long_ctx and registry.LONG_CONTEXT[arch_n] == "skip":
        rec["skipped"] = "long_500k inapplicable (see DESIGN.md)"
        return rec
    try:
        cfg = registry.get_config(arch_n, long_context=long_ctx)
        mesh = make_production_mesh(multi_pod=multi_pod)
        fsdp = arch_n in registry.FSDP_ARCHS
        mtok = registry.MICROBATCH_TOKENS.get(arch_n, 8192)
        kw = dict(fsdp=fsdp, microbatch_tokens=mtok)
        if optimized:
            kw.update(OPTIMIZED_TRAIN.get(arch_n, OPTIMIZED_TRAIN["_default"]))
            if shape.kind == "decode" and fsdp:
                kw["ws_moe"] = True
            if shape.kind == "decode":
                kw["kv_int8"] = True
        t0 = time.perf_counter()  # monotonic: NTP steps must not skew
        bundle = api.build(cfg, mesh, shape, **kw)
        lowered = bundle.fn.lower(*bundle.abstract_args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        rec.update(ok=True, optimized=optimized, lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1),
                   num_microbatches=bundle.num_microbatches,
                   params=param_count(cfg),
                   active_params=active_param_count(cfg))
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            rec["cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if k in ("flops", "bytes accessed", "transcendentals",
                         "utilization operand 0 {}")
                or k.startswith("bytes accessed")}
        except Exception as e:  # pragma: no cover
            rec["cost_analysis_error"] = str(e)[:200]
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                rec["memory_analysis"] = {
                    k: int(getattr(ma, k)) for k in dir(ma)
                    if k.endswith("size_in_bytes") and not k.startswith("_")}
        except Exception as e:  # pragma: no cover
            rec["memory_analysis_error"] = str(e)[:200]
        try:
            hlo = compiled.as_text()
            rec["collectives"] = collective_bytes(hlo)
            rec["hlo_len"] = len(hlo)
        except Exception as e:  # pragma: no cover
            rec["collectives_error"] = str(e)[:200]
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"[:500]
        rec["traceback"] = traceback.format_exc()[-1500:]
    if verbose:
        status = "OK " if rec.get("ok") else ("SKIP" if "skipped" in rec
                                              else "FAIL")
        print(f"[{status}] {arch_n:24s} {shape_name:12s} {rec['mesh']:8s}"
              f" compile={rec.get('compile_s', '-')}s", flush=True)
        if "error" in rec:
            print("   ", rec["error"][:300], flush=True)
    return rec


def dryrun_roles(*, multi_pod: bool = False, ratios=(1, 2, 1),
                 n_collectors: int = 1, envs_per_collector: int = 1,
                 verbose: bool = True) -> dict:
    """Role-split sanity for the async MBRL pod path: split the
    production mesh into collector/model/policy sub-meshes
    (core/roles.py) and report their shapes and the role shardings the
    workers would jit against — plus how a collector FLEET of
    ``n_collectors`` spreads round-robin over the collector sub-mesh's
    devices, and how many simulated robots the fleet runs in total when
    each collector is an env FARM of ``envs_per_collector`` vmapped
    lanes (ISSUE 6). Pure mesh bookkeeping — nothing is allocated (512
    forced host devices stand in for the pod)."""
    from repro.core.roles import (batch_sharded, collector_sharding,
                                  replicated, split_roles)
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=multi_pod)
    roles = split_roles(mesh, ratios=tuple(ratios))
    fleet = {
        f"collector:{i}": str(next(iter(
            collector_sharding(roles.collector, i).device_set)))
        for i in range(n_collectors)}
    rec = {"mesh": "2x16x16" if multi_pod else "16x16",
           "ratios": list(ratios), "roles": roles.describe(),
           "model_batch_sharding":
               str(batch_sharded(roles.model, roles.axis)),
           "policy_param_sharding": str(replicated(roles.policy)),
           "n_collectors": n_collectors,
           "envs_per_collector": envs_per_collector,
           "sim_robots_total": n_collectors * envs_per_collector,
           "fleet_devices": fleet,
           "collector_devices_total": int(roles.collector.devices.size)}
    if verbose:
        print(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--roles", action="store_true",
                    help="report the async-MBRL role split of the "
                         "production mesh and exit")
    ap.add_argument("--role-ratios", default="1,2,1")
    ap.add_argument("--n-collectors", type=int, default=4,
                    help="with --roles: report the fleet's round-robin "
                         "device assignment on the collector sub-mesh")
    ap.add_argument("--envs-per-collector", type=int, default=1,
                    help="with --roles: report the fleet's total "
                         "simulated-robot count when each collector "
                         "farms B vmapped env lanes")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--resume", action="store_true",
                    help="skip combos already present in --out")
    args = ap.parse_args()

    if args.roles:
        dryrun_roles(multi_pod=args.multi_pod,
                     ratios=tuple(int(x) for x in
                                  args.role_ratios.split(",")),
                     n_collectors=args.n_collectors,
                     envs_per_collector=args.envs_per_collector)
        return

    archs = registry.ARCH_IDS if (args.all or not args.arch) \
        else [registry.normalize(args.arch)]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    out_path = Path(args.out)
    results = []
    done = set()
    if args.resume and out_path.exists():
        results = json.loads(out_path.read_text())
        done = {(r["arch"], r["shape"], r["mesh"]) for r in results
                if r.get("ok") or "skipped" in r}

    for mp in meshes:
        mesh_name = "2x16x16" if mp else "16x16"
        for a in archs:
            for s in shapes:
                if (a, s, mesh_name) in done:
                    continue
                rec = dryrun_one(a, s, multi_pod=mp)
                results = [r for r in results
                           if not (r["arch"] == rec["arch"]
                                   and r["shape"] == rec["shape"]
                                   and r["mesh"] == rec["mesh"])]
                results.append(rec)
                out_path.write_text(json.dumps(results, indent=1))
    n_ok = sum(1 for r in results if r.get("ok"))
    n_skip = sum(1 for r in results if "skipped" in r)
    print(f"\n{n_ok} ok, {n_skip} skipped, "
          f"{len(results) - n_ok - n_skip} failed -> {out_path}")


if __name__ == "__main__":
    main()
