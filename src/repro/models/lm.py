"""Decoder-LM assembly for the dense / moe / vlm / ssm / hybrid families.

All step functions here are LOCAL (they run inside ``jax.shard_map``);
global entry points with jit + shardings are built in ``repro.models.api``.

Parameter pytree::

  params = {
    "embed":  {table, head, ln_f},
    "layers": block params stacked over num_layers (lax.scan consumes them),
    "shared": hybrid-only shared attention+mlp block (one set of weights,
              applied every ``attn_every`` layers — Zamba2-style),
  }

Hybrid layer order: for layer index i, the shared transformer block runs
BEFORE mamba layer i whenever i % attn_every == 0. Internally the stack is
processed as ``n_full`` groups of ``attn_every`` mamba layers plus a tail
group, so each shared-block invocation's KV cache is collected naturally.

KV-cache layouts are chosen statically by ``layers.decode_mode`` — see the
kind "W"/"A"/"B" docstring there.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ModelConfig, ShardCtx
from repro.optim.optimizers import Optimizer, apply_updates

AUX_COEF = 0.01


def _remat(fn, ctx):
    """Layer remat. With ctx.save_collectives, forward collective outputs
    are stored instead of re-communicated in the backward recompute."""
    if getattr(ctx, "save_collectives", False):
        policy = jax.checkpoint_policies.save_only_these_names("tp_reduce")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)

# --------------------------------------------------------------------------
# per-layer block init/spec


def _block_kind(cfg: ModelConfig) -> str:
    if cfg.family in ("dense", "vlm"):
        return "dense"
    if cfg.family == "moe":
        return "moe"
    if cfg.family in ("ssm", "hybrid"):
        return "ssm"
    raise ValueError(cfg.family)


def init_block(cfg: ModelConfig, ctx: ShardCtx, key):
    kind = _block_kind(cfg)
    if kind == "dense":
        k1, k2 = jax.random.split(key)
        return {"attn": L.init_attn(cfg, ctx, k1),
                "mlp": L.init_mlp(cfg, ctx, k2)}
    if kind == "moe":
        k1, k2 = jax.random.split(key)
        return {"attn": L.init_attn(cfg, ctx, k1),
                "moe": M.init_moe(cfg, ctx, k2)}
    return {"mamba": S.init_mamba(cfg, ctx, key)}


def spec_block(cfg: ModelConfig, ctx: ShardCtx):
    kind = _block_kind(cfg)
    if kind == "dense":
        return {"attn": L.spec_attn(cfg, ctx), "mlp": L.spec_mlp(cfg, ctx)}
    if kind == "moe":
        return {"attn": L.spec_attn(cfg, ctx), "moe": M.spec_moe(cfg, ctx)}
    return {"mamba": S.spec_mamba(cfg, ctx)}


def _shared_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, family="dense")


def init_params(cfg: ModelConfig, ctx: ShardCtx, key):
    k_emb, k_layers, k_shared = jax.random.split(key, 3)
    keys = jax.random.split(k_layers, cfg.num_layers)
    params = {
        "embed": L.init_embed(cfg, ctx, k_emb),
        "layers": jax.vmap(lambda k: init_block(cfg, ctx, k))(keys),
    }
    if cfg.family == "hybrid":
        scfg = _shared_cfg(cfg)
        k1, k2 = jax.random.split(k_shared)
        params["shared"] = {"attn": L.init_attn(scfg, ctx, k1),
                            "mlp": L.init_mlp(scfg, ctx, k2)}
    return params


def _stack_spec(spec):
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), spec,
                        is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg: ModelConfig, ctx: ShardCtx):
    specs = {
        "embed": L.spec_embed(cfg, ctx),
        "layers": _stack_spec(spec_block(cfg, ctx)),
    }
    if cfg.family == "hybrid":
        scfg = _shared_cfg(cfg)
        specs["shared"] = {"attn": L.spec_attn(scfg, ctx),
                           "mlp": L.spec_mlp(scfg, ctx)}
    return specs


def _hybrid_groups(cfg: ModelConfig):
    k = cfg.attn_every
    n_full = cfg.num_layers // k
    tail = cfg.num_layers - n_full * k
    return k, n_full, tail


def n_shared_invocations(cfg: ModelConfig) -> int:
    if cfg.family != "hybrid" or not cfg.attn_every:
        return 0
    _, n_full, tail = _hybrid_groups(cfg)
    return n_full + (1 if tail else 0)


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)


def _mamba_scan(cfg, ctx, lp_stacked, h, *, remat, collect):
    def body(h, lp):
        if collect:
            h, (st, tx, tbc) = S.mamba_forward(cfg, ctx, lp["mamba"], h,
                                               return_state=True)
            return h, (st, tx, tbc)
        return S.mamba_forward(cfg, ctx, lp["mamba"], h), ()

    if remat:
        body = _remat(body, ctx)
    return jax.lax.scan(body, h, lp_stacked)


def stack_forward(cfg: ModelConfig, ctx: ShardCtx, params, x, positions, *,
                  remat: bool = False, collect_cache: bool = False):
    """Run the whole layer stack. Returns (h, aux_loss_sum, cache_ys).

    cache_ys (when collect_cache):
      dense/moe: (k, v) stacked over L
      ssm:       (ssm_state, tail_x, tail_bc) stacked over L
      hybrid:    dict(ssm=…, conv_x=…, conv_bc=…, k=…, v=…) — kv stacked
                 over shared-block invocations.
    """
    kind = _block_kind(cfg)

    if cfg.family == "hybrid":
        return _hybrid_forward(cfg, ctx, params, x, positions, remat=remat,
                               collect_cache=collect_cache)

    if kind == "ssm":
        h, ys = _mamba_scan(cfg, ctx, params["layers"], x, remat=remat,
                            collect=collect_cache)
        return h, jnp.zeros((), jnp.float32), ys

    def body(h, lp):
        aux = jnp.zeros((), jnp.float32)
        if collect_cache:
            h, (k, v) = L.attn_forward(cfg, ctx, lp["attn"], h, positions,
                                       return_kv=True)
            ys = (k, v)
        else:
            h = L.attn_forward(cfg, ctx, lp["attn"], h, positions)
            ys = ()
        if kind == "moe":
            h, aux = M.moe_forward(cfg, ctx, lp["moe"], h)
        else:
            h = L.mlp_forward(cfg, ctx, lp["mlp"], h)
        return h, (aux, ys)

    rg = getattr(ctx, "remat_group", 0)
    if remat and rg > 1 and not collect_cache:
        # two-level remat: save only every rg-th layer input; the recompute
        # count per layer is unchanged, but it lets the microbatch count
        # shrink (fewer FSDP weight gathers) at bounded memory (§Perf h2).
        n_full = cfg.num_layers // rg
        tail = cfg.num_layers - n_full * rg
        lp = params["layers"]
        grouped = jax.tree.map(
            lambda a: a[:n_full * rg].reshape((n_full, rg) + a.shape[1:]),
            lp)

        def group_body(h, glp):
            h, (auxs, ys) = jax.lax.scan(body, h, glp)
            return h, auxs.sum()

        group_body = _remat(group_body, ctx)
        h, auxs = jax.lax.scan(group_body, x, grouped)
        aux_total = auxs.sum()
        if tail:
            lp_tail = jax.tree.map(lambda a: a[n_full * rg:], lp)
            h, tail_aux = group_body(h, lp_tail)
            aux_total = aux_total + tail_aux
        return h, aux_total, ()
    if remat:
        body = _remat(body, ctx)
    h, (auxs, ys) = jax.lax.scan(body, x, params["layers"])
    return h, auxs.sum(), ys


def _hybrid_forward(cfg, ctx, params, x, positions, *, remat, collect_cache):
    k, n_full, tail = _hybrid_groups(cfg)
    scfg = _shared_cfg(cfg)
    shared = params["shared"]
    lp_all = params["layers"]
    lp_main = jax.tree.map(
        lambda a: a[:n_full * k].reshape((n_full, k) + a.shape[1:]), lp_all)
    lp_tail = jax.tree.map(lambda a: a[n_full * k:], lp_all)

    def shared_block(h):
        if collect_cache:
            h, (kk, vv) = L.attn_forward(scfg, ctx, shared["attn"], h,
                                         positions, return_kv=True)
        else:
            h = L.attn_forward(scfg, ctx, shared["attn"], h, positions)
            kk = vv = ()
        h = L.mlp_forward(scfg, ctx, shared["mlp"], h)
        return h, (kk, vv)

    def group(h, glp):
        h, kv = shared_block(h)
        h, ys = _mamba_scan(cfg, ctx, glp, h, remat=remat,
                            collect=collect_cache)
        return h, (kv, ys)

    if remat:
        group = _remat(group, ctx)
    h, (kvs, inner) = jax.lax.scan(group, x, lp_main)
    if tail:
        h, (kv_t, ys_t) = group(h, lp_tail)
        if collect_cache:
            kvs = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b[None]], 0), kvs, kv_t)
            # inner ys: (n_full, k, ...) + tail (tail, ...) -> flat (L, ...)
            inner = jax.tree.map(
                lambda a, b: jnp.concatenate(
                    [a.reshape((-1,) + a.shape[2:]), b], 0), inner, ys_t)
    elif collect_cache:
        inner = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), inner)
    aux = jnp.zeros((), jnp.float32)
    if not collect_cache:
        return h, aux, ()
    st, tx, tbc = inner
    kk, vv = kvs
    return h, aux, {"ssm": st, "conv_x": tx, "conv_bc": tbc, "k": kk, "v": vv}


def embed_inputs(cfg: ModelConfig, ctx: ShardCtx, params, batch):
    tokens = batch["tokens"]
    x = L.embed_tokens(cfg, ctx, params["embed"], tokens)
    if cfg.modality == "vision" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
    return x, jnp.arange(x.shape[1])


def loss_forward(cfg: ModelConfig, ctx: ShardCtx, params, batch, *,
                 remat: bool = True):
    x, positions = embed_inputs(cfg, ctx, params, batch)
    h, aux, _ = stack_forward(cfg, ctx, params, x, positions, remat=remat)
    s, c = L.lm_loss(cfg, ctx, params["embed"], h, batch["labels"])
    return s, c, aux


# --------------------------------------------------------------------------
# training step (microbatched grad accumulation + optimizer)


def _axes_in_spec(spec: P):
    used = set()
    for dim in spec:
        if dim is None:
            continue
        for ax in (dim,) if isinstance(dim, str) else tuple(dim):
            used.add(ax)
    return used


# --------------------------------------------------------------------------
# ZeRO-1: shard Adam m/v over the dp axes on each parameter's LAST dim


def zero1_plan(cfg: ModelConfig, ctx: ShardCtx, pspecs, params_abs):
    """Tree of bools: which leaves get dp-sharded optimizer state.

    A leaf qualifies when its LOCAL last dim divides dp_size and no dp axis
    already appears in its spec (FSDP leaves are naturally sharded)."""
    flat_p = jax.tree.leaves(params_abs)
    flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    plan = []
    for p, sp in zip(flat_p, flat_s):
        ok = False
        if p.ndim >= 1 and p.size >= ctx.dp_size:
            used = _axes_in_spec(sp)
            if not any(ax in used for ax in ctx.dp_axes):
                last = tuple(sp)[-1] if len(sp) >= p.ndim else None
                tp_div = ctx.tp_size if (last == ctx.tp_axis or
                                         (isinstance(last, tuple)
                                          and ctx.tp_axis in last)) else 1
                local_last = p.shape[-1] // tp_div
                ok = local_last % ctx.dp_size == 0 and local_last > 0
        plan.append(ok)
    return jax.tree.unflatten(jax.tree.structure(params_abs), plan)


def zero1_opt_specs(cfg: ModelConfig, ctx: ShardCtx, pspecs, params_abs):
    """PartitionSpecs for Adam m/v under ZeRO-1."""
    plan = zero1_plan(cfg, ctx, pspecs, params_abs)
    flat_p = jax.tree.leaves(params_abs)
    flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_ok = jax.tree.leaves(plan)
    out = []
    dp = tuple(ctx.dp_axes) if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    for p, sp, ok in zip(flat_p, flat_s, flat_ok):
        if not ok:
            out.append(sp)
            continue
        dims = list(tuple(sp)) + [None] * (p.ndim - len(tuple(sp)))
        last = dims[-1]
        if last is None:
            dims[-1] = dp
        elif isinstance(last, str):
            dims[-1] = (last,) + tuple(ctx.dp_axes)
        else:
            dims[-1] = tuple(last) + tuple(ctx.dp_axes)
        out.append(P(*dims))
    return jax.tree.unflatten(jax.tree.structure(params_abs), out)


def make_train_step(cfg: ModelConfig, ctx: ShardCtx, opt: Optimizer,
                    num_microbatches: int = 1, *, loss_fwd=None, specs=None,
                    zero1=None):
    """Microbatched grad-accumulation train step (LOCAL, inside shard_map).

    ``loss_fwd(params, batch) -> (sum_loss, count, aux)`` defaults to the
    decoder-LM loss; encdec passes its own. ``specs`` must match the param
    tree (used for cross-replica grad reductions and the global grad-norm).
    ``zero1``: bool tree from zero1_plan — Adam m/v arrive dp-sharded on the
    last dim; grads/params are sliced to match, updates all-gathered back.
    """
    if loss_fwd is None:
        loss_fwd = lambda p, b: loss_forward(cfg, ctx, p, b)
    if specs is None:
        specs = param_specs(cfg, ctx)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    dp_all = tuple(ctx.dp_axes)

    def _axis_size(ax):
        try:
            return jax.lax.axis_size(ax)
        except AttributeError:      # jax<0.6: psum of 1 == axis size
            return jax.lax.psum(1, ax)      # (constant-folded by XLA)

    def _dp_idx():
        idx = jnp.zeros((), jnp.int32)
        for ax in dp_all:
            idx = idx * _axis_size(ax) + jax.lax.axis_index(ax)
        return idx

    def z_slice(tree):
        if zero1 is None:
            return tree
        def f(x, ok):
            if not ok:
                return x
            chunk = x.shape[-1] // ctx.dp_size
            return jax.lax.dynamic_slice_in_dim(
                x, _dp_idx() * chunk, chunk, axis=x.ndim - 1)
        return jax.tree.map(f, tree, zero1)

    def z_gather(tree):
        if zero1 is None:
            return tree
        def f(x, ok):
            if not ok:
                return x
            return jax.lax.all_gather(x, dp_all, axis=x.ndim - 1, tiled=True)
        return jax.tree.map(f, tree, zero1)

    def train_step(params, opt_state, batch):
        nm = num_microbatches

        def split_mb(x):
            return x.reshape((nm, x.shape[0] // nm) + x.shape[1:])

        mb = jax.tree.map(split_mb, batch)
        count = (batch["labels"] >= 0).sum()
        count_global = jax.lax.psum(count, ctx.dp_axes) \
            if ctx.dp_size > 1 else count
        denom = jnp.maximum(count_global, 1).astype(jnp.float32)

        def loss_fn(p, b):
            s, c, aux = loss_fwd(p, b)
            return s / denom + AUX_COEF * aux / nm, s

        def micro(carry, b):
            g_acc, s_acc = carry
            (_, s), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
            g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                 g_acc, g)
            return (g_acc, s_acc + s), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(
            micro, (g0, jnp.zeros((), jnp.float32)), mb)

        # Cross-replica gradient reduction: each param's grad is reduced
        # over every dp axis its spec does NOT shard it along. (FSDP-stored
        # params were already reduce-scattered over their storage axis by
        # the all_gather VJP inside the layer.)
        flat_g, tdef = jax.tree.flatten(grads)
        red = []
        for g, sp in zip(flat_g, flat_specs):
            axes = tuple(ax for ax in ctx.dp_axes
                         if ax not in _axes_in_spec(sp))
            red.append(jax.lax.psum(g, axes) if axes else g)
        grads = jax.tree.unflatten(tdef, red)

        # Global grad-norm: shard-local squared norms of SHARDED leaves are
        # partial sums and must be psummed over the axes in their spec;
        # replicated leaves contribute once. Doing this correctly keeps the
        # clip scale identical on every device (otherwise replicated params
        # would desync across tp shards).
        sq_by_axes: Dict[tuple, Any] = {}
        flat_g2 = jax.tree.leaves(grads)
        for g, sp in zip(flat_g2, flat_specs):
            axes = tuple(sorted(_axes_in_spec(sp) & set((ctx.tp_axis,)
                                                        + tuple(ctx.dp_axes))))
            sq_by_axes[axes] = sq_by_axes.get(axes, 0.0) + jnp.vdot(g, g).real
        total = jnp.zeros((), jnp.float32)
        for axes, val in sq_by_axes.items():
            total = total + (jax.lax.psum(val, axes) if axes else val)
        gnorm = jnp.sqrt(total + 1e-12)
        scale = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        updates, opt_state = opt.update(z_slice(grads), opt_state,
                                        z_slice(params))
        params = apply_updates(params, z_gather(updates))
        loss_total = jax.lax.psum(loss_sum, ctx.dp_axes) \
            if ctx.dp_size > 1 else loss_sum
        metrics = {"loss": loss_total / denom, "gnorm": gnorm}
        return params, opt_state, metrics

    return train_step


# --------------------------------------------------------------------------
# KV / state cache


def init_cache(cfg: ModelConfig, ctx: ShardCtx, global_batch: int,
               seq_len: int, *, prefilled: bool = False):
    """GLOBAL cache arrays (zeros). ``prefilled`` marks index=seq_len (for
    dry-run decode inputs the values are placeholders anyway)."""
    mode = L.decode_mode(cfg, ctx, global_batch, seq_len)
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.hd
    B = global_batch
    idx0 = seq_len if prefilled else 0
    cache: Dict[str, Any] = {"index": jnp.asarray(idx0, jnp.int32)}
    kind = _block_kind(cfg)
    n_inv = n_shared_invocations(cfg)
    s_c = mode["s_cache"]
    kvh = cfg.num_kv_heads

    quant = getattr(ctx, "kv_int8", False)

    def kv_arrays(n_layers):
        kdt = jnp.int8 if quant else dt
        kk = jnp.zeros((n_layers, B, s_c, kvh, hd), kdt)
        return kk, jnp.zeros_like(kk)

    def scale_arrays(n_layers):
        sc = jnp.zeros((n_layers, B, s_c, kvh, 1), jnp.float32)
        return sc, jnp.zeros_like(sc)

    if kind in ("dense", "moe"):
        cache["k"], cache["v"] = kv_arrays(cfg.num_layers)
        if quant:
            cache["k_scale"], cache["v_scale"] = scale_arrays(cfg.num_layers)
        cache["pos"] = jnp.full((s_c,), -1, jnp.int32)
    else:
        H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        gn2 = 2 * cfg.ssm_groups * cfg.ssm_state
        cache["ssm"] = jnp.zeros((cfg.num_layers, B, H, Pd, N), jnp.float32)
        cache["conv_x"] = jnp.zeros(
            (cfg.num_layers, B, cfg.ssm_conv - 1, cfg.d_inner), dt)
        cache["conv_bc"] = jnp.zeros(
            (cfg.num_layers, B, cfg.ssm_conv - 1, gn2), dt)
        if n_inv:
            cache["k"], cache["v"] = kv_arrays(n_inv)
            cache["pos"] = jnp.full((s_c,), -1, jnp.int32)
    return cache


def cache_specs(cfg: ModelConfig, ctx: ShardCtx, global_batch: int,
                seq_len: int):
    mode = L.decode_mode(cfg, ctx, global_batch, seq_len)
    dp = tuple(ctx.dp_axes) if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    b_ax = dp if mode["batch_dp"] else None
    kind = _block_kind(cfg)
    specs: Dict[str, Any] = {"index": P()}
    seq_axes = mode["seq_axes"]
    s_ax = None
    if seq_axes:
        s_ax = tuple(seq_axes) if len(seq_axes) > 1 else seq_axes[0]
    kv_sharded_in_cache = (mode["kind"] in ("A", "W")
                           and cfg.num_kv_heads % ctx.tp_size == 0)
    kv_ax = ctx.tp_axis if kv_sharded_in_cache else None
    kv_spec = P(None, b_ax, s_ax, kv_ax, None)
    if kind in ("dense", "moe"):
        specs["k"] = kv_spec
        specs["v"] = kv_spec
        if getattr(ctx, "kv_int8", False):
            specs["k_scale"] = kv_spec
            specs["v_scale"] = kv_spec
        specs["pos"] = P(s_ax)
    else:
        tp = ctx.tp_axis
        specs["ssm"] = P(None, b_ax, tp, None, None)
        specs["conv_x"] = P(None, b_ax, None, tp)
        specs["conv_bc"] = P(None, b_ax, None, None)
        if n_shared_invocations(cfg):
            specs["k"] = kv_spec
            specs["v"] = kv_spec
            specs["pos"] = P(s_ax)
    return specs


# --------------------------------------------------------------------------
# prefill step


def make_prefill(cfg: ModelConfig, ctx: ShardCtx, global_batch: int,
                 seq_len: int):
    mode = L.decode_mode(cfg, ctx, global_batch, seq_len)
    kind = _block_kind(cfg)

    def pack_kv(k, v, S_):
        """k/v: (Linv, B, S, KV?, hd) local -> cache layout + pos array."""
        s_c = mode["s_cache"]
        if mode["kind"] == "W":
            keepn = min(s_c, S_)
            pos = jnp.arange(S_ - keepn, S_)
            slots = pos % s_c
            def ring(a):
                out = jnp.zeros(a.shape[:2] + (s_c,) + a.shape[3:], a.dtype)
                return out.at[:, :, slots].set(a[:, :, S_ - keepn:])
            posarr = jnp.full((s_c,), -1, jnp.int32).at[slots].set(pos)
            return ring(k), ring(v), posarr
        pad = s_c - S_
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        posarr = jnp.concatenate([jnp.arange(S_, dtype=jnp.int32),
                                  jnp.full((pad,), -1, jnp.int32)])
        if mode["seq_axes"]:
            n = L.axes_size(ctx, mode["seq_axes"])
            s_loc = s_c // n
            st = L._axes_index(ctx, mode["seq_axes"]) * s_loc
            kp = jax.lax.dynamic_slice_in_dim(kp, st, s_loc, axis=2)
            vp = jax.lax.dynamic_slice_in_dim(vp, st, s_loc, axis=2)
            posarr = jax.lax.dynamic_slice_in_dim(posarr, st, s_loc, axis=0)
        return kp, vp, posarr

    def prefill(params, batch):
        x, positions = embed_inputs(cfg, ctx, params, batch)
        h, _, ys = stack_forward(cfg, ctx, params, x, positions,
                                 collect_cache=True)
        logits = L.lm_logits_last(cfg, ctx, params["embed"], h[:, -1])
        S_ = x.shape[1]
        cache: Dict[str, Any] = {"index": jnp.asarray(S_, jnp.int32)}
        if kind in ("dense", "moe"):
            k, v = ys
            if getattr(ctx, "kv_int8", False):
                kq, ks = L.kv_quantize(k)
                vq, vs = L.kv_quantize(v)
                cache["k"], cache["v"], cache["pos"] = pack_kv(kq, vq, S_)
                cache["k_scale"], cache["v_scale"], _ = pack_kv(ks, vs, S_)
            else:
                cache["k"], cache["v"], cache["pos"] = pack_kv(k, v, S_)
        elif cfg.family == "hybrid":
            cache.update(ssm=ys["ssm"], conv_x=ys["conv_x"],
                         conv_bc=ys["conv_bc"])
            cache["k"], cache["v"], cache["pos"] = pack_kv(
                ys["k"], ys["v"], S_)
        else:
            st, tx, tbc = ys
            cache.update(ssm=st, conv_x=tx, conv_bc=tbc)
        return logits, cache

    return prefill


# --------------------------------------------------------------------------
# decode step


def make_decode(cfg: ModelConfig, ctx: ShardCtx, global_batch: int,
                seq_len: int):
    mode = L.decode_mode(cfg, ctx, global_batch, seq_len)
    kind = _block_kind(cfg)
    scfg = _shared_cfg(cfg) if cfg.family == "hybrid" else None

    def decode(params, cache, token):
        index = cache["index"]
        x = L.embed_tokens(cfg, ctx, params["embed"], token)  # (B, 1, d)
        new_cache = dict(cache)

        if kind in ("dense", "moe"):
            quant = getattr(ctx, "kv_int8", False)

            def body(carry, xs):
                h, pos = carry
                if quant:
                    lp, kc, vc, ksc, vsc = xs
                    h, kc, vc, pos, ksc, vsc = L.attn_decode(
                        cfg, ctx, lp["attn"], h, kc, vc, pos, index, mode,
                        k_scale=ksc, v_scale=vsc)
                else:
                    lp, kc, vc = xs
                    h, kc, vc, pos = L.attn_decode(
                        cfg, ctx, lp["attn"], h, kc, vc, pos, index, mode)
                if kind == "moe":
                    h, _ = M.moe_forward(cfg, ctx, lp["moe"], h)
                else:
                    h = L.mlp_forward(cfg, ctx, lp["mlp"], h)
                ys = (kc, vc, ksc, vsc) if quant else (kc, vc)
                return (h, pos), ys

            if quant:
                (h, pos), (ks, vs, kscs, vscs) = jax.lax.scan(
                    body, (x, cache["pos"]),
                    (params["layers"], cache["k"], cache["v"],
                     cache["k_scale"], cache["v_scale"]))
                new_cache.update(k=ks, v=vs, pos=pos, k_scale=kscs,
                                 v_scale=vscs)
            else:
                (h, pos), (ks, vs) = jax.lax.scan(
                    body, (x, cache["pos"]),
                    (params["layers"], cache["k"], cache["v"]))
                new_cache.update(k=ks, v=vs, pos=pos)
        elif cfg.family == "hybrid":
            kk, n_full, tail = _hybrid_groups(cfg)
            shared = params["shared"]
            lp_all = params["layers"]
            lp_main = jax.tree.map(
                lambda a: a[:n_full * kk].reshape((n_full, kk) + a.shape[1:]),
                lp_all)
            lp_tail = jax.tree.map(lambda a: a[n_full * kk:], lp_all)
            st_all, tx_all, tbc_all = (cache["ssm"], cache["conv_x"],
                                       cache["conv_bc"])
            def reshape_main(a):
                return a[:n_full * kk].reshape((n_full, kk) + a.shape[1:])
            def mamba_group(h, glp, gst, gtx, gtbc):
                def inner(carry, xs):
                    h = carry
                    lp, st, tx, tbc = xs
                    h, st, tx, tbc = S.mamba_decode(
                        cfg, ctx, lp["mamba"], h, st, tx, tbc)
                    return h, (st, tx, tbc)
                return jax.lax.scan(inner, h, (glp, gst, gtx, gtbc))

            def group(carry, xs):
                h, pos = carry
                glp, kc, vc, gst, gtx, gtbc = xs
                h, kc, vc, pos = L.attn_decode(
                    scfg, ctx, shared["attn"], h, kc, vc, pos, index, mode)
                h = L.mlp_forward(scfg, ctx, shared["mlp"], h)
                h, states = mamba_group(h, glp, gst, gtx, gtbc)
                return (h, pos), ((kc, vc), states)

            n_inv = n_shared_invocations(cfg)
            k_main = cache["k"][:n_full]
            v_main = cache["v"][:n_full]
            (h, pos), ((ks, vs), states) = jax.lax.scan(
                group, (x, cache["pos"]),
                (lp_main, k_main, v_main,
                 reshape_main(st_all), reshape_main(tx_all),
                 reshape_main(tbc_all)))
            sts, txs, tbcs = states  # (n_full, kk, ...)
            flat = lambda a: a.reshape((-1,) + a.shape[2:])
            sts, txs, tbcs = flat(sts), flat(txs), flat(tbcs)
            if tail:
                (h, pos), ((kt, vt), st_t) = group(
                    (h, pos),
                    (lp_tail, cache["k"][n_full], cache["v"][n_full],
                     st_all[n_full * kk:], tx_all[n_full * kk:],
                     tbc_all[n_full * kk:]))
                ks = jnp.concatenate([ks, kt[None]], 0)
                vs = jnp.concatenate([vs, vt[None]], 0)
                sts = jnp.concatenate([sts, st_t[0]], 0)
                txs = jnp.concatenate([txs, st_t[1]], 0)
                tbcs = jnp.concatenate([tbcs, st_t[2]], 0)
            new_cache.update(ssm=sts, conv_x=txs, conv_bc=tbcs,
                             k=ks, v=vs, pos=pos)
        else:  # pure ssm
            def body(carry, xs):
                h = carry
                lp, st, tx, tbc = xs
                h, st, tx, tbc = S.mamba_decode(
                    cfg, ctx, lp["mamba"], h, st, tx, tbc)
                return h, (st, tx, tbc)

            h, (sts, txs, tbcs) = jax.lax.scan(
                body, x, (params["layers"], cache["ssm"], cache["conv_x"],
                          cache["conv_bc"]))
            new_cache.update(ssm=sts, conv_x=txs, conv_bc=tbcs)

        logits = L.lm_logits_last(cfg, ctx, params["embed"], h[:, 0])
        new_cache["index"] = index + 1
        return logits, new_cache

    return decode


# --------------------------------------------------------------------------
# per-slot cache (continuous-batching serve tier)
#
# The lock-step decode above shares ONE scalar ``index`` and one (S,)
# ``pos`` across the whole batch — every request must start and stop
# together. The serve tier instead treats each batch row as an
# independent SLOT at its own position, so requests stream through a
# single compiled decode program (repro.serve).


def _slot_mode(cfg: ModelConfig, ctx: ShardCtx, n_slots: int,
               seq_len: int):
    """decode_mode restricted to the layouts the serve tier supports:
    attention KV families, fp cache, kind "A", no sliding window. When
    the slot count does not divide dp the cache is replicated instead of
    seq-sharded (serve keeps state batch-resident)."""
    if _block_kind(cfg) not in ("dense", "moe"):
        raise ValueError(
            f"serve tier needs an attention KV cache; family "
            f"{cfg.family!r} has none (ssm/hybrid state is lock-step only)")
    if cfg.attn_window:
        raise ValueError("serve tier does not support sliding-window "
                         "(ring) caches")
    if getattr(ctx, "kv_int8", False):
        raise ValueError("serve tier does not support int8 KV caches")
    mode = L.decode_mode(cfg, ctx, n_slots, seq_len)
    if mode["kind"] != "A":
        raise ValueError(
            f"serve tier needs a kind-'A' cache (num_kv_heads divisible "
            f"by tp), got kind {mode['kind']!r}")
    if mode["seq_axes"]:
        mode = dict(mode, seq_axes=(), s_cache=seq_len + 1)
    return mode


def init_cache_slots(cfg: ModelConfig, ctx: ShardCtx, n_slots: int,
                     seq_len: int):
    """GLOBAL slot-pool cache (all slots empty): per-slot ``index`` (B,)
    token counts and ``pos`` (B, s_cache) position maps (-1 empty)."""
    mode = _slot_mode(cfg, ctx, n_slots, seq_len)
    dt = jnp.dtype(cfg.dtype)
    s_c = mode["s_cache"]
    k = jnp.zeros((cfg.num_layers, n_slots, s_c, cfg.num_kv_heads, cfg.hd),
                  dt)
    return {"index": jnp.zeros((n_slots,), jnp.int32),
            "k": k, "v": jnp.zeros_like(k),
            "pos": jnp.full((n_slots, s_c), -1, jnp.int32)}


def cache_specs_slots(cfg: ModelConfig, ctx: ShardCtx, n_slots: int,
                      seq_len: int):
    mode = _slot_mode(cfg, ctx, n_slots, seq_len)
    dp = tuple(ctx.dp_axes) if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    b_ax = dp if mode["batch_dp"] else None
    kv_ax = ctx.tp_axis if cfg.num_kv_heads % ctx.tp_size == 0 else None
    kv_spec = P(None, b_ax, None, kv_ax, None)
    return {"index": P(b_ax), "k": kv_spec, "v": kv_spec,
            "pos": P(b_ax, None)}


def make_prefill_slots(cfg: ModelConfig, ctx: ShardCtx, global_batch: int,
                       seq_len: int):
    """Prefill one serve admission bucket (fixed shapes, per-row prompt
    lengths). Two differences from make_prefill make right-padded prompts
    decode correctly: logits come from each row's LAST REAL token
    (``prompt_len - 1``, not ``seq_len - 1``), and cache positions at and
    after the prompt are marked empty (-1) so the padding's KV is never
    attended. Causality already keeps the real tokens' KV independent of
    the padding to their right."""
    mode = _slot_mode(cfg, ctx, global_batch, seq_len)

    def prefill(params, batch, prompt_len):
        x, positions = embed_inputs(cfg, ctx, params, batch)
        h, _, (k, v) = stack_forward(cfg, ctx, params, x, positions,
                                     collect_cache=True)
        S_ = x.shape[1]
        last = jnp.clip(prompt_len - 1, 0, S_ - 1)
        h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
        logits = L.lm_logits_last(cfg, ctx, params["embed"], h_last)
        s_c = mode["s_cache"]
        pad = s_c - S_
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        posarr = jnp.arange(s_c, dtype=jnp.int32)[None, :]
        posarr = jnp.where(posarr < prompt_len[:, None], posarr, -1)
        cache = {"index": prompt_len.astype(jnp.int32),
                 "k": kp, "v": vp, "pos": posarr}
        return logits, cache

    return prefill


def make_decode_slots(cfg: ModelConfig, ctx: ShardCtx, n_slots: int,
                      seq_len: int):
    """Continuous-batching decode: ONE new token for every ACTIVE slot.

    ``cache`` is the slot-pool layout of :func:`init_cache_slots`,
    ``token`` is (n_slots, 1) pending tokens and ``active`` is (n_slots,)
    bool. Inactive slots are computed but never written (drop-mode
    scatter), so admissions and retirements between calls never change
    shapes — the step compiles exactly once per (n_slots, seq_len)."""
    mode = _slot_mode(cfg, ctx, n_slots, seq_len)
    kind = _block_kind(cfg)

    def decode(params, cache, token, active):
        index = cache["index"]
        x = L.embed_tokens(cfg, ctx, params["embed"], token)

        def body(carry, xs):
            h, pos = carry
            lp, kc, vc = xs
            h, kc, vc, pos = L.attn_decode_slots(
                cfg, ctx, lp["attn"], h, kc, vc, pos, index, active, mode)
            if kind == "moe":
                h, _ = M.moe_forward(cfg, ctx, lp["moe"], h)
            else:
                h = L.mlp_forward(cfg, ctx, lp["mlp"], h)
            return (h, pos), (kc, vc)

        (h, pos), (ks, vs) = jax.lax.scan(
            body, (x, cache["pos"]),
            (params["layers"], cache["k"], cache["v"]))
        logits = L.lm_logits_last(cfg, ctx, params["embed"], h[:, 0])
        new_cache = dict(cache, k=ks, v=vs, pos=pos,
                         index=index + active.astype(jnp.int32))
        return logits, new_cache

    return decode
