"""Mixture-of-Experts FFN with explicit TPU-pod sharding.

On a single TPU device (tp_size == fsdp_size == 1) expert dispatch is
DROPLESS: (token, choice) pairs sort by expert and run through the ragged
``kernels/gmm`` grouped matmul — no zero-padded capacity buffers and no
overflow drops (``moe_forward_dropless``).

Sharded meshes use capacity buffers; two strategies, chosen statically
from the config/mesh:

* ``ep`` (expert-parallel) — experts sharded over the ``model`` axis
  (requires num_experts % tp == 0). Each device dispatches its LOCAL tokens
  into capacity buffers for all experts, computes only its local experts,
  and a single psum over ``model`` combines expert outputs. (The all-to-all
  dispatch variant lives in ``moe_forward_a2a`` and is the §Perf
  hillclimb alternative.)
* ``tp`` (tensor-parallel experts) — for small expert counts (e.g. Mixtral
  E=8 < tp=16): every device computes ALL experts but only a d_ff/tp slice,
  combined by the same output psum.

Either way the big expert weights can additionally be STORED sharded over
the ``data`` axis (FSDP / ZeRO-3) and are all-gathered just-in-time inside
the layer; autodiff turns that gather into the matching reduce-scatter.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.gmm import ops as gmm_ops
from repro.models.config import ModelConfig, ShardCtx
from repro.models.layers import (_dense_init, reduce_tp, rmsnorm,
                                 tp_index)


def moe_strategy(cfg: ModelConfig, ctx: ShardCtx) -> str:
    return "ep" if cfg.num_experts % ctx.tp_size == 0 else "tp"


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = math.ceil(cfg.top_k * tokens / cfg.num_experts * cfg.capacity_factor)
    return max(8, math.ceil(c / 8) * 8)


def _fsdp_gather(w, ctx: ShardCtx, axis: int):
    if ctx.fsdp_size > 1:
        return jax.lax.all_gather(w, ctx.fsdp_axis, axis=axis, tiled=True)
    return w


def _expert_ff(cfg: ModelConfig) -> int:
    return cfg.d_ff  # per-expert hidden size (already per-expert in configs)


def _route(cfg: ModelConfig, router, h):
    """Router logits -> (full probs (T, E), normalised combine weights
    (T, k), expert choices (T, k)) — the one routing definition shared by
    every dispatch strategy."""
    logits = jnp.dot(h, router.astype(h.dtype),
                     preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return probs, w, idx


def init_moe(cfg: ModelConfig, ctx: ShardCtx, key) -> Dict[str, Any]:
    d, f, e = cfg.d_model, _expert_ff(cfg), cfg.num_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.ones((d,), dt),
        "router": _dense_init(ks[0], (d, e), d, jnp.float32),
        "we1": _dense_init(ks[1], (e, d, f), d, dt),
        "we3": _dense_init(ks[2], (e, d, f), d, dt),
        "we2": _dense_init(ks[3], (e, f, d), f, dt),
    }


def spec_moe(cfg: ModelConfig, ctx: ShardCtx) -> Dict[str, Any]:
    tp, fs = ctx.tp_axis, (ctx.fsdp_axis if ctx.fsdp_size > 1 else None)
    if moe_strategy(cfg, ctx) == "ep":
        return {"ln": P(None), "router": P(None, None),
                "we1": P(tp, None, fs), "we3": P(tp, None, fs),
                "we2": P(tp, fs, None)}
    return {"ln": P(None), "router": P(None, None),
            "we1": P(None, fs, tp), "we3": P(None, fs, tp),
            "we2": P(None, tp, fs)}


def _dispatch(cfg: ModelConfig, xt, idx, cap):
    """Scatter tokens into per-expert capacity buffers.

    xt: (T, d); idx: (T, k) expert choices. Returns
    (buf (E, cap+1, d) — slot ``cap`` is the overflow bin, slots (T, k)).
    """
    T, d = xt.shape
    E, k = cfg.num_experts, cfg.top_k
    buf = jnp.zeros((E, cap + 1, d), xt.dtype)
    counts = jnp.zeros((E,), jnp.int32)
    slots = []
    eye = jnp.arange(E, dtype=jnp.int32)
    for j in range(k):
        ej = idx[:, j]
        oh = (ej[:, None] == eye[None, :]).astype(jnp.int32)      # (T, E)
        within = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1        # (T,)
        pos = jnp.take(counts, ej) + within
        counts = counts + oh.sum(0)
        slot = jnp.where(pos < cap, pos, cap)
        buf = buf.at[ej, slot].set(xt)
        slots.append(slot)
    return buf, jnp.stack(slots, axis=1), counts


def moe_forward_ws(cfg: ModelConfig, ctx: ShardCtx, p, x):
    """Weight-stationary MoE for tiny token counts (decode, §Perf h3).

    FSDP-stored expert weights are NEVER gathered (28GB/token for
    qwen3-235B!); instead the handful of decode tokens are all-gathered
    across the FSDP axis (~1MB), every device computes its (expert-shard x
    f-slice) partial for the whole token group, and one small psum over
    (tp, fsdp) combines. Falls back to the standard path when there is no
    FSDP sharding."""
    B, S, d = x.shape
    T = B * S
    h = rmsnorm(x, p["ln"]).reshape(T, d)
    fs_ax, fs = ctx.fsdp_axis, ctx.fsdp_size
    hg = jax.lax.all_gather(h, fs_ax, axis=0, tiled=True)    # (T*fs, d)
    Tg = hg.shape[0]
    E, k = cfg.num_experts, cfg.top_k
    probs, w, idx = _route(cfg, p["router"], hg)
    cap = capacity(cfg, Tg)
    buf, slots, counts = _dispatch(cfg, hg, idx, cap)
    if moe_strategy(cfg, ctx) == "ep":
        e_loc = E // ctx.tp_size
        off = tp_index(ctx) * e_loc
        local = jax.lax.dynamic_slice_in_dim(buf, off, e_loc, axis=0)
        w1, w3, w2 = p["we1"], p["we3"], p["we2"]   # LOCAL f-slices, no gather
        a = jnp.einsum("ecd,edf->ecf", local, w1,
                       preferred_element_type=jnp.float32)
        g = jnp.einsum("ecd,edf->ecf", local, w3,
                       preferred_element_type=jnp.float32)
        hh = (jax.nn.silu(a) * g).astype(x.dtype)
        out_loc = jnp.einsum("ecf,efd->ecd", hh, w2,
                             preferred_element_type=jnp.float32)
        out_full = jnp.zeros((E, cap + 1, d), jnp.float32)
        out_full = jax.lax.dynamic_update_slice_in_dim(
            out_full, out_loc, off, axis=0)
    else:
        # tp-expert strategy: we1 (E, d/fs, f/tp), we2 (E, f/tp, d/fs).
        # Slice the tokens' d dim to this shard's fsdp slice; the first
        # matmul is then PARTIAL over d and must be psum'd over fsdp
        # before the nonlinearity.
        d_loc = p["we1"].shape[1]
        doff = jax.lax.axis_index(fs_ax) * d_loc
        buf_d = jax.lax.dynamic_slice_in_dim(buf, doff, d_loc, axis=2)
        a = jax.lax.psum(jnp.einsum("ecd,edf->ecf", buf_d, p["we1"],
                                    preferred_element_type=jnp.float32),
                         fs_ax)
        g = jax.lax.psum(jnp.einsum("ecd,edf->ecf", buf_d, p["we3"],
                                    preferred_element_type=jnp.float32),
                         fs_ax)
        hh = (jax.nn.silu(a) * g).astype(x.dtype)
        out_d = jnp.einsum("ecf,efd->ecd", hh, p["we2"],
                           preferred_element_type=jnp.float32)
        # out_d: (E, C, d_loc) partial over f (tp); psum over tp, then
        # reassemble full d via all_gather over fsdp
        out_d = jax.lax.psum(out_d, ctx.tp_axis)
        out_full = jax.lax.all_gather(out_d, fs_ax, axis=2, tiled=True)
        y = jnp.zeros((Tg, d), jnp.float32)
        for j in range(k):
            yj = out_full[idx[:, j], slots[:, j]]
            keep = (slots[:, j] < cap).astype(jnp.float32)
            y = y + w[:, j, None] * keep[:, None] * yj
        start = jax.lax.axis_index(fs_ax) * T
        y = jax.lax.dynamic_slice_in_dim(y, start, T, axis=0)
        return (x + y.reshape(B, S, d).astype(x.dtype),
                jnp.zeros((), jnp.float32))
    y = jnp.zeros((Tg, d), jnp.float32)
    for j in range(k):
        yj = out_full[idx[:, j], slots[:, j]]
        keep = (slots[:, j] < cap).astype(jnp.float32)
        y = y + w[:, j, None] * keep[:, None] * yj
    # combine partial f-slices (fsdp) and expert shards (tp) in one psum,
    # then take back this shard's own tokens
    y = jax.lax.psum(y, (ctx.tp_axis, fs_ax))
    start = jax.lax.axis_index(fs_ax) * T
    y = jax.lax.dynamic_slice_in_dim(y, start, T, axis=0)
    return x + y.reshape(B, S, d).astype(x.dtype), jnp.zeros((), jnp.float32)


def moe_forward_dropless(cfg: ModelConfig, p, x):
    """Dropless single-device MoE on the ragged grouped-matmul kernel.

    Every (token, choice) pair is a row: rows are sorted by expert, each
    expert FFN runs one ragged ``grouped_matmul`` over exactly its own
    rows (``group_sizes = bincount(expert ids)``), and outputs scatter
    back to token order. No zero-padded capacity buffers, no overflow
    bin, no dropped tokens — T*k rows of FLOPs however skewed the
    routing, with idle experts as zero-size groups."""
    B, S, d = x.shape
    T = B * S
    h = rmsnorm(x, p["ln"]).reshape(T, d)
    E, k = cfg.num_experts, cfg.top_k
    probs, w, idx = _route(cfg, p["router"], h)                   # (T, k)

    eflat = idx.reshape(-1)                                       # (T*k,)
    order = jnp.argsort(eflat)
    rows = h[order // k]                   # token row of each sorted pair
    counts = jnp.bincount(eflat, length=E)

    a = gmm_ops.grouped_matmul(rows, p["we1"], counts)
    g = gmm_ops.grouped_matmul(rows, p["we3"], counts)
    hh = (jax.nn.silu(a.astype(jnp.float32))
          * g.astype(jnp.float32)).astype(x.dtype)
    out = gmm_ops.grouped_matmul(hh, p["we2"], counts)            # (T*k, d)

    y = jnp.zeros_like(out).at[order].set(out).reshape(T, k, d)
    y = (w[..., None] * y.astype(jnp.float32)).sum(1)

    frac = counts.astype(jnp.float32) / jnp.maximum(T * k, 1)
    aux = E * jnp.sum(frac * probs.mean(0))
    return x + y.reshape(B, S, d).astype(x.dtype), aux


def moe_forward(cfg: ModelConfig, ctx: ShardCtx, p, x):
    """x: (B, S, d) local. Returns (x + moe(x), aux_loss)."""
    if getattr(ctx, "ws_moe", False) and ctx.fsdp_size > 1:
        return moe_forward_ws(cfg, ctx, p, x)
    if ctx.tp_size == 1 and ctx.fsdp_size == 1 and gmm_ops._on_tpu():
        # single device, ragged Pallas kernel available: dropless path,
        # no capacity buffers. (Off-TPU the ragged dispatch falls to the
        # pure-jnp oracle, which materialises per-row gathered expert
        # weights — keep the capacity einsum path there.)
        return moe_forward_dropless(cfg, p, x)
    B, S, d = x.shape
    T = B * S
    h = rmsnorm(x, p["ln"]).reshape(T, d)
    E, k = cfg.num_experts, cfg.top_k
    probs, w, idx = _route(cfg, p["router"], h)                   # (T, k)

    cap = capacity(cfg, T)
    buf, slots, counts = _dispatch(cfg, h, idx, cap)

    f = _expert_ff(cfg)
    if moe_strategy(cfg, ctx) == "ep":
        e_loc = E // ctx.tp_size
        off = tp_index(ctx) * e_loc
        local = jax.lax.dynamic_slice_in_dim(buf, off, e_loc, axis=0)
        w1 = _fsdp_gather(p["we1"], ctx, axis=2)
        w3 = _fsdp_gather(p["we3"], ctx, axis=2)
        w2 = _fsdp_gather(p["we2"], ctx, axis=1)
        a = jnp.einsum("ecd,edf->ecf", local, w1,
                       preferred_element_type=jnp.float32)
        g = jnp.einsum("ecd,edf->ecf", local, w3,
                       preferred_element_type=jnp.float32)
        hh = (jax.nn.silu(a) * g).astype(x.dtype)
        out_loc = jnp.einsum("ecf,efd->ecd", hh, w2,
                             preferred_element_type=jnp.float32)
        out_full = jnp.zeros((E, cap + 1, d), jnp.float32)
        out_full = jax.lax.dynamic_update_slice_in_dim(
            out_full, out_loc, off, axis=0)
    else:  # tp-sharded experts (f split over model axis)
        w1 = _fsdp_gather(p["we1"], ctx, axis=1)
        w3 = _fsdp_gather(p["we3"], ctx, axis=1)
        w2 = _fsdp_gather(p["we2"], ctx, axis=2)
        a = jnp.einsum("ecd,edf->ecf", buf, w1,
                       preferred_element_type=jnp.float32)
        g = jnp.einsum("ecd,edf->ecf", buf, w3,
                       preferred_element_type=jnp.float32)
        hh = (jax.nn.silu(a) * g).astype(x.dtype)
        out_full = jnp.einsum("ecf,efd->ecd", hh, w2,
                              preferred_element_type=jnp.float32)

    y = jnp.zeros((T, d), jnp.float32)
    for j in range(k):
        yj = out_full[idx[:, j], slots[:, j]]                     # (T, d)
        keep = (slots[:, j] < cap).astype(jnp.float32)
        y = y + w[:, j, None] * keep[:, None] * yj
    # combine in bf16: halves the EP psum bytes and the saved residual
    y = reduce_tp(y.astype(x.dtype), ctx)

    # Switch-style load-balance auxiliary loss (local tokens)
    frac = counts.astype(jnp.float32) / jnp.maximum(T * k, 1)
    imp = probs.mean(0)
    aux = E * jnp.sum(frac * imp)
    return x + y.reshape(B, S, d).astype(x.dtype), aux
