"""Mamba-2 (SSD) block with head-sharded tensor parallelism.

Heads (d_inner / head_dim of them) are sharded over the ``model`` axis; the
B/C group projections (ngroups=1) are replicated so every shard can run its
heads independently; the output projection is row-parallel with a psum.
The gated RMSNorm normalises over the GLOBAL d_inner via a scalar psum so
the sharded computation is bit-identical to the unsharded one.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.ssd import ops as ssd_ops
from repro.models.config import ModelConfig, ShardCtx
from repro.models.layers import _dense_init, matmul, psum_tp, reduce_tp

# conv channels = [x (d_inner, sharded)] + [B,C (2*G*N, replicated)]


def init_mamba(cfg: ModelConfig, ctx: ShardCtx, key) -> Dict[str, Any]:
    d, di = cfg.d_model, cfg.d_inner
    H, G, N, w = cfg.ssm_heads, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.ones((d,), dt),
        "wz": _dense_init(ks[0], (d, di), d, dt),
        "wx": _dense_init(ks[1], (d, di), d, dt),
        "wbc": _dense_init(ks[2], (d, 2 * G * N), d, dt),
        "wdt": _dense_init(ks[3], (d, H), d, dt),
        "conv_x": _dense_init(ks[4], (w, di), w, dt),
        "conv_bc": _dense_init(ks[5], (w, 2 * G * N), w, dt),
        "conv_bx": jnp.zeros((di,), dt),
        "conv_bbc": jnp.zeros((2 * G * N,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),      # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gn": jnp.ones((di,), dt),
        "wo": _dense_init(ks[0], (di, d), di, dt),
    }


def spec_mamba(cfg: ModelConfig, ctx: ShardCtx) -> Dict[str, Any]:
    tp = ctx.tp_axis
    return {
        "ln": P(None), "wz": P(None, tp), "wx": P(None, tp),
        "wbc": P(None, None), "wdt": P(None, tp),
        "conv_x": P(None, tp), "conv_bc": P(None, None),
        "conv_bx": P(tp), "conv_bbc": P(None),
        "A_log": P(tp), "D": P(tp), "dt_bias": P(tp),
        "gn": P(tp), "wo": P(tp, None),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, C); w: (W, C); b: (C,)."""
    W = w.shape[0]
    xf = x.astype(jnp.float32)
    y = xf * w[-1].astype(jnp.float32)
    for i in range(W - 1):
        shift = W - 1 - i
        y = y + jnp.pad(xf, ((0, 0), (shift, 0), (0, 0)))[:, :-shift] \
            * w[i].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def _gated_norm(y, z, w, ctx: ShardCtx, di_global: int, eps: float = 1e-5):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ss = psum_tp((yf * yf).sum(-1), ctx) / di_global
    return (yf * jax.lax.rsqrt(ss + eps)[..., None]
            * w.astype(jnp.float32)).astype(y.dtype)


def _project(cfg, ctx, p, h):
    z = matmul(h, p["wz"])
    xin = matmul(h, p["wx"])
    bc = matmul(h, p["wbc"])
    dt = matmul(h, p["wdt"]).astype(jnp.float32)
    return z, xin, bc, dt


def mamba_forward(cfg: ModelConfig, ctx: ShardCtx, p, x, *,
                  return_state: bool = False, initial_state=None):
    """x: (B, S, d) local. Optional state passthrough for prefill."""
    B, S, d = x.shape
    G, N, Pd = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    H_loc = cfg.ssm_heads // ctx.tp_size
    hn = _rms(x, p["ln"])
    z, xin, bc, dt = _project(cfg, ctx, p, hn)
    # pre-conv tails become the decode-time conv state (x part is
    # tp-sharded, bc part replicated — kept as separate cache entries)
    W = cfg.ssm_conv - 1
    pad_s = max(W - S, 0)
    tail_x = jnp.pad(xin, ((0, 0), (pad_s, 0), (0, 0)))[:, -W:]
    tail_bc = jnp.pad(bc, ((0, 0), (pad_s, 0), (0, 0)))[:, -W:]
    xin = jax.nn.silu(
        _causal_conv(xin, p["conv_x"], p["conv_bx"]).astype(jnp.float32)
    ).astype(x.dtype)
    bc = jax.nn.silu(
        _causal_conv(bc, p["conv_bc"], p["conv_bbc"]).astype(jnp.float32)
    ).astype(x.dtype)
    B_, C_ = bc[..., :G * N].reshape(B, S, G, N), \
        bc[..., G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(B, S, H_loc, Pd)
    res = ssd_ops.ssd(xh, dt, A, B_, C_, chunk=cfg.ssm_chunk,
                      initial_state=initial_state,
                      return_final_state=return_state)
    y, final_state = res if return_state else (res, None)
    y = (y.astype(jnp.float32)
         + p["D"].astype(jnp.float32)[None, None, :, None]
         * xh.astype(jnp.float32)).astype(x.dtype)
    y = _gated_norm(y.reshape(B, S, -1), z, p["gn"], ctx, cfg.d_inner)
    out = reduce_tp(matmul(y, p["wo"]), ctx)
    out = x + out
    if return_state:
        return out, (final_state, tail_x, tail_bc)
    return out


def mamba_decode(cfg: ModelConfig, ctx: ShardCtx, p, x, ssm_state,
                 conv_x_state, conv_bc_state):
    """x: (B, 1, d); ssm_state: (B, H_loc, P, N);
    conv_x_state: (B, W-1, di_loc); conv_bc_state: (B, W-1, 2GN)."""
    B = x.shape[0]
    G, N, Pd, W = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_conv
    H_loc = cfg.ssm_heads // ctx.tp_size
    hn = _rms(x, p["ln"])
    z, xin, bc, dt = _project(cfg, ctx, p, hn)
    win_x = jnp.concatenate([conv_x_state, xin], axis=1)   # (B, W, di_loc)
    win_bc = jnp.concatenate([conv_bc_state, bc], axis=1)  # (B, W, 2GN)
    new_conv_x, new_conv_bc = win_x[:, 1:], win_bc[:, 1:]
    cx = (win_x.astype(jnp.float32)
          * p["conv_x"].astype(jnp.float32)).sum(1) \
        + p["conv_bx"].astype(jnp.float32)
    cbc = (win_bc.astype(jnp.float32)
           * p["conv_bc"].astype(jnp.float32)).sum(1) \
        + p["conv_bbc"].astype(jnp.float32)
    xin1 = jax.nn.silu(cx).astype(x.dtype)                 # (B, di_loc)
    bc1 = jax.nn.silu(cbc).astype(x.dtype)                 # (B, 2GN)
    B_t = bc1[:, :G * N].reshape(B, G, N)
    C_t = bc1[:, G * N:].reshape(B, G, N)
    dt1 = jax.nn.softplus(dt[:, 0] + p["dt_bias"])     # (B, H_loc)
    A = -jnp.exp(p["A_log"])
    y, new_ssm = ssd_ops.ssd_decode_step(
        ssm_state, xin1.reshape(B, H_loc, Pd), dt1, A, B_t, C_t)
    y = (y.astype(jnp.float32)
         + p["D"].astype(jnp.float32)[None, :, None]
         * xin1.reshape(B, H_loc, Pd).astype(jnp.float32)).astype(x.dtype)
    y = _gated_norm(y.reshape(B, 1, -1), z, p["gn"], ctx, cfg.d_inner)
    out = psum_tp(matmul(y, p["wo"]), ctx)
    return x + out, new_ssm, new_conv_x, new_conv_bc


def _rms(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)
