"""Encoder-decoder backbone (Seamless-M4T style) — audio frontend stubbed.

The speech encoder's conformer/conv frontend is NOT implemented (per the
assignment carve-out): ``input_specs`` supplies precomputed frame
embeddings (B, S_enc, d). This module implements the transformer encoder
over those embeddings and the text decoder with self+cross attention.

Cache layout: self-attention cache follows layers.decode_mode; the cross
cache is static after prefill (k/v projected from encoder output once).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.flash_attention import ops as attn_ops
from repro.models import layers as L
from repro.models.config import ModelConfig, ShardCtx
from repro.models.lm import _stack_spec  # noqa: F401 (reused below)


def _enc_layers(cfg: ModelConfig) -> int:
    return cfg.encoder_layers or cfg.num_layers


# ---------------------------------------------------------------- params


def init_enc_block(cfg: ModelConfig, ctx: ShardCtx, key):
    k1, k2 = jax.random.split(key)
    return {"attn": L.init_attn(cfg, ctx, k1),
            "mlp": L.init_mlp(cfg, ctx, k2)}


def init_dec_block(cfg: ModelConfig, ctx: ShardCtx, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"self": L.init_attn(cfg, ctx, k1),
            "cross": L.init_attn(cfg, ctx, k2),
            "mlp": L.init_mlp(cfg, ctx, k3)}


def init_params(cfg: ModelConfig, ctx: ShardCtx, key):
    ke, kd, kemb, kn = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, _enc_layers(cfg))
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "embed": L.init_embed(cfg, ctx, kemb),
        "enc_layers": jax.vmap(lambda k: init_enc_block(cfg, ctx, k))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_dec_block(cfg, ctx, k))(dec_keys),
        "enc_ln": jnp.ones((cfg.d_model,), jnp.dtype(cfg.dtype)),
    }


def param_specs(cfg: ModelConfig, ctx: ShardCtx):
    eb = {"attn": L.spec_attn(cfg, ctx), "mlp": L.spec_mlp(cfg, ctx)}
    db = {"self": L.spec_attn(cfg, ctx), "cross": L.spec_attn(cfg, ctx),
          "mlp": L.spec_mlp(cfg, ctx)}
    return {"embed": L.spec_embed(cfg, ctx),
            "enc_layers": _stack_spec(eb),
            "dec_layers": _stack_spec(db),
            "enc_ln": P(None)}


# ---------------------------------------------------------------- forward


def encode(cfg: ModelConfig, ctx: ShardCtx, params, enc_embeds, *,
           remat: bool = False):
    positions = jnp.arange(enc_embeds.shape[1])
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))

    def body(h, lp):
        h = L.attn_forward(cfg, ctx, lp["attn"], h, positions, causal=False)
        h = L.mlp_forward(cfg, ctx, lp["mlp"], h)
        return h, ()

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rmsnorm(h, params["enc_ln"])


def _cross_attn(cfg, ctx, p, x, enc_out, *, collect=False):
    """Full cross-attention (train/prefill). q from x, kv from enc_out."""
    h = L.rmsnorm(x, p["ln"])
    hp, h_loc, kv_sharded, kv_loc = L.head_layout(cfg, ctx)
    hd = cfg.hd
    B, Sq = x.shape[:2]
    q = L.matmul(h, p["wq"]).reshape(B, Sq, h_loc, hd)
    k = L.matmul(enc_out, p["wk"]).reshape(B, enc_out.shape[1], -1, hd)
    v = L.matmul(enc_out, p["wv"]).reshape(B, enc_out.shape[1], -1, hd)
    o = attn_ops.attention(q, k, v, causal=False)
    o = L.matmul(o.reshape(B, Sq, -1), p["wo"])
    o = L.psum_tp(o, ctx)
    if collect:
        return x + o, (k, v)
    return x + o


def _cross_attn_decode(cfg, ctx, p, x, k_cache, v_cache, enc_len):
    """x: (B, 1, d); cross caches (B, S_enc_loc, KV_loc, hd), static."""
    B = x.shape[0]
    hp, h_loc, _, _ = L.head_layout(cfg, ctx)
    h = L.rmsnorm(x, p["ln"])
    q = L.matmul(h, p["wq"]).reshape(B, h_loc, cfg.hd)
    valid = jnp.arange(k_cache.shape[1]) < enc_len
    o, _ = L._masked_decode(q, k_cache, v_cache, valid)
    o = L.matmul(o.reshape(B, 1, -1).astype(x.dtype), p["wo"])
    o = L.psum_tp(o, ctx)
    return x + o


def decoder_forward(cfg: ModelConfig, ctx: ShardCtx, params, tokens, enc_out,
                    *, remat: bool = False, collect_cache: bool = False):
    x = L.embed_tokens(cfg, ctx, params["embed"], tokens)
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        if collect_cache:
            h, (sk, sv) = L.attn_forward(cfg, ctx, lp["self"], h, positions,
                                         return_kv=True)
            h, (ck, cv) = _cross_attn(cfg, ctx, lp["cross"], h, enc_out,
                                      collect=True)
            ys = (sk, sv, ck, cv)
        else:
            h = L.attn_forward(cfg, ctx, lp["self"], h, positions)
            h = _cross_attn(cfg, ctx, lp["cross"], h, enc_out)
            ys = ()
        h = L.mlp_forward(cfg, ctx, lp["mlp"], h)
        return h, ys

    if remat:
        body = jax.checkpoint(body)
    h, ys = jax.lax.scan(body, x, params["dec_layers"])
    return h, ys


def loss_forward(cfg: ModelConfig, ctx: ShardCtx, params, batch, *,
                 remat: bool = True):
    enc_out = encode(cfg, ctx, params, batch["enc_embeds"], remat=remat)
    h, _ = decoder_forward(cfg, ctx, params, batch["tokens"], enc_out,
                           remat=remat)
    s, c = L.lm_loss(cfg, ctx, params["embed"], h, batch["labels"])
    return s, c, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------- serving


def init_cache(cfg: ModelConfig, ctx: ShardCtx, global_batch: int,
               seq_len: int, *, prefilled: bool = False):
    mode = L.decode_mode(cfg, ctx, global_batch, seq_len)
    dt = jnp.dtype(cfg.dtype)
    s_c = mode["s_cache"]
    B, kvh, hd, Ld = global_batch, cfg.num_kv_heads, cfg.hd, cfg.num_layers
    z = lambda s: jnp.zeros((Ld, B, s, kvh, hd), dt)
    return {
        "index": jnp.asarray(seq_len if prefilled else 0, jnp.int32),
        "k": z(s_c), "v": z(s_c),
        "pos": jnp.full((s_c,), -1, jnp.int32),
        "cross_k": z(seq_len), "cross_v": z(seq_len),
        "enc_len": jnp.asarray(seq_len, jnp.int32),
    }


def cache_specs(cfg: ModelConfig, ctx: ShardCtx, global_batch: int,
                seq_len: int):
    mode = L.decode_mode(cfg, ctx, global_batch, seq_len)
    dp = tuple(ctx.dp_axes) if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    b_ax = dp if mode["batch_dp"] else None
    s_ax = None
    if mode["seq_axes"]:
        sa = mode["seq_axes"]
        s_ax = tuple(sa) if len(sa) > 1 else sa[0]
    kv_ax = ctx.tp_axis if cfg.num_kv_heads % ctx.tp_size == 0 else None
    kv_spec = P(None, b_ax, s_ax, kv_ax, None)
    cross_spec = P(None, b_ax, s_ax, kv_ax, None)
    return {"index": P(), "k": kv_spec, "v": kv_spec, "pos": P(s_ax),
            "cross_k": cross_spec, "cross_v": cross_spec, "enc_len": P()}


def make_prefill(cfg: ModelConfig, ctx: ShardCtx, global_batch: int,
                 seq_len: int):
    mode = L.decode_mode(cfg, ctx, global_batch, seq_len)

    def prefill(params, batch):
        enc_out = encode(cfg, ctx, params, batch["enc_embeds"])
        h, ys = decoder_forward(cfg, ctx, params, batch["tokens"], enc_out,
                                collect_cache=True)
        sk, sv, ck, cv = ys
        logits = L.lm_logits_last(cfg, ctx, params["embed"], h[:, -1])
        S_ = batch["tokens"].shape[1]
        s_c = mode["s_cache"]
        pad = s_c - S_
        padkv = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0),
                                      (0, 0)))
        cache = {
            "index": jnp.asarray(S_, jnp.int32),
            "k": padkv(sk), "v": padkv(sv),
            "pos": jnp.concatenate([jnp.arange(S_, dtype=jnp.int32),
                                    jnp.full((pad,), -1, jnp.int32)]),
            "cross_k": ck, "cross_v": cv,
            "enc_len": jnp.asarray(enc_out.shape[1], jnp.int32),
        }
        return logits, cache

    return prefill


def make_decode(cfg: ModelConfig, ctx: ShardCtx, global_batch: int,
                seq_len: int):
    mode = L.decode_mode(cfg, ctx, global_batch, seq_len)

    def decode(params, cache, token):
        index = cache["index"]
        x = L.embed_tokens(cfg, ctx, params["embed"], token)

        def body(carry, xs):
            h, pos = carry
            lp, kc, vc, ck, cv = xs
            h, kc, vc, pos = L.attn_decode(
                cfg, ctx, lp["self"], h, kc, vc, pos, index, mode)
            h = _cross_attn_decode(cfg, ctx, lp["cross"], h, ck, cv,
                                   cache["enc_len"])
            h = L.mlp_forward(cfg, ctx, lp["mlp"], h)
            return (h, pos), (kc, vc)

        (h, pos), (ks, vs) = jax.lax.scan(
            body, (x, cache["pos"]),
            (params["dec_layers"], cache["k"], cache["v"],
             cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache)
        new_cache.update(k=ks, v=vs, pos=pos, index=index + 1)
        logits = L.lm_logits_last(cfg, ctx, params["embed"], h[:, 0])
        return logits, new_cache

    return decode
