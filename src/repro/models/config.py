"""Model / sharding configuration dataclasses shared by every architecture."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Static description of the mesh axes a step function runs under.

    Everything is explicit (manual tensor-parallel inside shard_map); axis
    sizes are static so local shapes are known at trace time. A 1x1 mesh
    gives the single-device path used by smoke tests.
    """
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    dp_size: int = 1
    tp_size: int = 1
    seq_shard_decode: bool = False  # shard KV cache over dp on sequence dim
    fsdp_axis: Optional[str] = None  # store big expert weights sharded here
    fsdp_size: int = 1
    rs_ag: bool = False              # reduce_scatter+all_gather row-parallel
                                     # reductions (exact psum replacement)
    save_collectives: bool = False   # remat policy keeps collective outputs
    bf16_grad_reduce: bool = False   # backward dx psums carried in bf16
    remat_group: int = 0             # two-level remat group size (0 = flat)
    ws_moe: bool = False             # weight-stationary MoE (decode path)
    kv_int8: bool = False            # int8-quantised KV cache (decode)

    @property
    def all_axes(self):
        return self.dp_axes + (self.tp_axis,)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # --- attention flavour
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_window: int = 0           # 0 = full attention; >0 = sliding window
    # --- MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba-2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0            # hybrid: shared attn block every k layers
    # --- encoder/decoder
    encoder_layers: int = 0
    # --- modality frontend stub: "text" | "vision" | "audio"
    modality: str = "text"
    mlp_type: str = "swiglu"       # swiglu | gelu
    dtype: str = "bfloat16"
    # --- source citation (paper / model card this config reproduces)
    source: str = ""
    # --- training
    max_grad_norm: float = 1.0
    lr: float = 3e-4

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    def padded_heads(self, tp: int) -> int:
        """q heads padded up so tp divides them (zero-output pad heads)."""
        return math.ceil(self.num_heads / tp) * tp if tp > 1 else self.num_heads

    def padded_vocab(self, tp: int) -> int:
        mult = 128 * max(tp, 1)
        return math.ceil(self.vocab_size / mult) * mult

    def padded_ff(self, tp: int) -> int:
        mult = max(tp, 1)
        return math.ceil(self.d_ff / mult) * mult

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_ssm_family(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def validate(self, ctx: ShardCtx) -> None:
        tp = ctx.tp_size
        hp = self.padded_heads(tp)
        assert hp % tp == 0
        if self.family != "ssm" and self.num_kv_heads:
            if self.num_kv_heads % tp != 0:
                # replicated-kv path: every shard's q heads must map to ONE
                # kv head (see layers.py) — verify statically here.
                h_loc = hp // tp
                g = hp // self.num_kv_heads if self.num_kv_heads else 1
                for i in range(tp):
                    lo, hi = i * h_loc, (i + 1) * h_loc - 1
                    lo_kv = min(lo, self.num_heads - 1) // g
                    hi_kv = min(hi, self.num_heads - 1) // g
                    if lo_kv != hi_kv:
                        raise ValueError(
                            f"{self.name}: replicated-kv requires one kv head "
                            f"per shard (shard {i} spans {lo_kv}..{hi_kv})")
        if self.is_ssm_family and self.ssm_heads % tp != 0:
            raise ValueError(f"{self.name}: ssm heads {self.ssm_heads} "
                             f"not divisible by tp={tp}")


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"
    microbatch: int = 0            # 0 -> auto


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in
                (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
