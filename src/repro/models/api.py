"""Global entry points: shard_map + jit wrappers around the local steps.

``build(cfg, mesh, shape)`` returns a ``StepBundle`` with the jitted global
function plus abstract (ShapeDtypeStruct) inputs and NamedShardings — the
dry-run lowers ``bundle.fn`` against ``bundle.abstract_args`` without ever
allocating parameters.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import encdec as E
from repro.models import lm as LM
from repro.models.config import InputShape, ModelConfig, ShardCtx
from repro.optim.optimizers import adam
from repro.utils.jit_stats import trace_counted


def shard_ctx(mesh, *, fsdp: bool = False, rs_ag: bool = False,
              save_collectives: bool = False, bf16_grad_reduce: bool = False,
              remat_group: int = 0, ws_moe: bool = False,
              seq_shard_decode: bool = False) -> ShardCtx:
    names = tuple(mesh.axis_names)
    assert "model" in names, names
    dp_axes = tuple(n for n in names if n != "model")
    dp_size = 1
    for n in dp_axes:
        dp_size *= mesh.shape[n]
    tp_size = mesh.shape["model"]
    fsdp_axis = "data" if (fsdp and "data" in dp_axes
                           and mesh.shape["data"] > 1) else None
    return ShardCtx(dp_axes=dp_axes, tp_axis="model", dp_size=dp_size,
                    tp_size=tp_size, seq_shard_decode=seq_shard_decode,
                    fsdp_axis=fsdp_axis,
                    fsdp_size=mesh.shape["data"] if fsdp_axis else 1,
                    rs_ag=rs_ag, save_collectives=save_collectives,
                    bf16_grad_reduce=bf16_grad_reduce,
                    remat_group=remat_group, ws_moe=ws_moe)


def _dp_spec_axis(ctx: ShardCtx):
    return tuple(ctx.dp_axes) if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]


def batch_struct(cfg: ModelConfig, shape: InputShape, ctx: ShardCtx):
    """Abstract batch + PartitionSpecs for train/prefill inputs."""
    B, S = shape.global_batch, shape.seq_len
    dp = _dp_spec_axis(ctx) if B % ctx.dp_size == 0 and B >= ctx.dp_size \
        else None
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    dt = jnp.dtype(cfg.dtype)
    batch, specs = {}, {}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        specs["enc_embeds"] = P(dp, None, None)
        batch["tokens"] = tok
        specs["tokens"] = P(dp, None)
    elif cfg.modality == "vision":
        n_patch = S // 8
        batch["patch_embeds"] = jax.ShapeDtypeStruct((B, n_patch, cfg.d_model),
                                                     dt)
        specs["patch_embeds"] = P(dp, None, None)
        batch["tokens"] = tok
        specs["tokens"] = P(dp, None)
    else:
        batch["tokens"] = tok
        specs["tokens"] = P(dp, None)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["labels"] = P(dp, None)
    return batch, specs


def pick_microbatches(cfg: ModelConfig, shape: InputShape, ctx: ShardCtx,
                      target_tokens: int = 8192) -> int:
    if shape.kind != "train":
        return 1
    if shape.microbatch:
        return shape.microbatch
    b_loc = max(shape.global_batch // ctx.dp_size, 1)
    want = max(1, (b_loc * shape.seq_len) // target_tokens)
    nm = 1
    for cand in range(1, b_loc + 1):
        if b_loc % cand == 0 and cand <= want:
            nm = cand
    return nm


@dataclasses.dataclass
class StepBundle:
    kind: str
    fn: Callable                    # jitted global step
    abstract_args: Tuple[Any, ...]  # ShapeDtypeStructs (pytrees)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    ctx: ShardCtx
    cfg: ModelConfig
    shape: InputShape
    num_microbatches: int = 1


def _shard_map(fn, mesh, in_specs, out_specs, check_vma=False):
    """check_vma=True enables replication tracking, which turns psum
    transposes into communication-free pbroadcasts (§Perf iteration 1)."""
    try:
        # AttributeError: jax<0.5 has no top-level shard_map at all
        # (jax._src.deprecations raises instead of returning the symbol);
        # TypeError: intermediate versions expose it under the older
        # check_rep kwarg name only.
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    except (AttributeError, TypeError):
        from jax.experimental.shard_map import shard_map as _sm
        return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma)


def _ns(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _mod(cfg: ModelConfig):
    return E if cfg.family == "encdec" else LM


def build(cfg: ModelConfig, mesh, shape: InputShape, *, fsdp: bool = False,
          microbatch_tokens: int = 8192, rs_ag: bool = False,
          save_collectives: bool = False, bf16_grad_reduce: bool = False,
          remat_group: int = 0, ws_moe: bool = False, zero1: bool = False,
          kv_int8: bool = False,
          check_vma: bool = False) -> StepBundle:
    ctx = shard_ctx(mesh, fsdp=fsdp, rs_ag=rs_ag,
                    save_collectives=save_collectives,
                    bf16_grad_reduce=bf16_grad_reduce,
                    remat_group=remat_group,
                    ws_moe=ws_moe and shape.kind == "decode")
    if kv_int8 and shape.kind in ("decode", "prefill") \
            and cfg.family in ("dense", "vlm", "moe"):
        import dataclasses as _dc
        ctx = _dc.replace(ctx, kv_int8=True)
    cfg.validate(ctx)
    mod = _mod(cfg)
    pspecs = mod.param_specs(cfg, ctx)
    params_abs = jax.eval_shape(
        lambda k: mod.init_params(cfg, ctx, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    B, S = shape.global_batch, shape.seq_len
    dp = _dp_spec_axis(ctx) if B % ctx.dp_size == 0 and B >= ctx.dp_size \
        else None

    if shape.kind == "train":
        nm = pick_microbatches(cfg, shape, ctx, microbatch_tokens)
        opt = adam(cfg.lr)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        zplan = None
        mv_specs = pspecs
        if zero1 and ctx.dp_size > 1:
            zplan = LM.zero1_plan(cfg, ctx, pspecs, params_abs)
            mv_specs = LM.zero1_opt_specs(cfg, ctx, pspecs, params_abs)
        opt_specs = type(opt_abs)(step=P(), mu=mv_specs, nu=mv_specs)
        batch_abs, bspecs = batch_struct(cfg, shape, ctx)
        if cfg.family == "encdec":
            loss_fwd = lambda p, b: E.loss_forward(cfg, ctx, p, b)
            local = LM.make_train_step(cfg, ctx, opt, nm, loss_fwd=loss_fwd,
                                       specs=pspecs, zero1=zplan)
        else:
            local = LM.make_train_step(cfg, ctx, opt, nm, specs=pspecs,
                                       zero1=zplan)
        in_specs = (pspecs, opt_specs, bspecs)
        out_specs = (pspecs, opt_specs, {"loss": P(), "gnorm": P()})
        gfn = _shard_map(local, mesh, in_specs, out_specs, check_vma)
        fn = jax.jit(gfn, in_shardings=_ns(mesh, in_specs),
                     out_shardings=_ns(mesh, out_specs), donate_argnums=(0, 1))
        return StepBundle("train", fn, (params_abs, opt_abs, batch_abs),
                          _ns(mesh, in_specs), _ns(mesh, out_specs), ctx, cfg,
                          shape, nm)

    if shape.kind == "prefill":
        batch_abs, bspecs = batch_struct(cfg, shape, ctx)
        local = mod.make_prefill(cfg, ctx, B, S)
        cspecs = mod.cache_specs(cfg, ctx, B, S)
        logits_spec = P(dp, None)
        in_specs = (pspecs, bspecs)
        out_specs = (logits_spec, cspecs)
        gfn = _shard_map(local, mesh, in_specs, out_specs, check_vma)
        fn = jax.jit(gfn, in_shardings=_ns(mesh, in_specs),
                     out_shardings=_ns(mesh, out_specs))
        return StepBundle("prefill", fn, (params_abs, batch_abs),
                          _ns(mesh, in_specs), _ns(mesh, out_specs), ctx, cfg,
                          shape)

    # decode: ONE new token against a seq_len-deep cache
    local = mod.make_decode(cfg, ctx, B, S)
    cache_abs = jax.eval_shape(
        functools.partial(mod.init_cache, cfg, ctx, B, S, prefilled=True))
    cspecs = mod.cache_specs(cfg, ctx, B, S)
    token_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_spec = P(dp, None)
    logits_spec = P(dp, None)
    in_specs = (pspecs, cspecs, tok_spec)
    out_specs = (logits_spec, cspecs)
    gfn = _shard_map(local, mesh, in_specs, out_specs, check_vma)
    fn = jax.jit(gfn, in_shardings=_ns(mesh, in_specs),
                 out_shardings=_ns(mesh, out_specs), donate_argnums=(1,))
    return StepBundle("decode", fn, (params_abs, cache_abs, token_abs),
                      _ns(mesh, in_specs), _ns(mesh, out_specs), ctx, cfg,
                      shape)


# --------------------------------------------------------------------------
# world-model plumbing: the predict_fn contract


def as_predict_fn(fn):
    """Pin ``fn`` to the world-model predict contract:
    ``predict(params, obs, act, key) -> next_obs`` with
    ``next_obs.shape == obs.shape``.

    This is the interface ``mbrl.algos.make_algo(predict_fn=...)`` swaps
    in for the ensemble fast path (and what the fused imagination step
    bypasses when ``predict_fn is None``). The wrapper checks the shape
    contract AT TRACE TIME — a world model that silently returns a
    different state layout fails at swap-in, not three layers deep in a
    rollout scan — and tags the callable (``is_predict_fn``) so engines
    can validate a handed-in model before wiring it to a worker."""

    @functools.wraps(fn)
    def predict(params, obs, act, key):
        out = fn(params, obs, act, key)
        if out.shape != obs.shape:
            raise ValueError(
                f"predict_fn contract: next_obs shape {out.shape} != "
                f"obs shape {obs.shape}")
        return out

    predict.is_predict_fn = True
    return predict


# --------------------------------------------------------------------------
# serve tier (repro.serve): cache growth + per-slot bundles


def grow_cache(cache, to_len: int):
    """Grow a decode KV cache's sequence capacity to ``to_len`` slots.

    Replaces the hand-rolled ``jnp.pad`` dance in the serving example:
    ``k``/``v`` (and int8 scales when present) gain zero slots on the
    sequence axis while ``pos`` gains EMPTY (-1) slots — a 0-padded pos
    would alias global position 0 and corrupt the attention mask, which
    is precisely the easy-to-miss bug this helper exists to prevent.
    Handles both the lock-step layout (pos ``(S,)``) and the serve
    slot-pool layout (pos ``(B, S)``). Returns a shallow copy; no-op
    values when already at ``to_len``.
    """
    if "k" not in cache or "pos" not in cache:
        raise ValueError("grow_cache needs an attention KV cache "
                         "(ssm/hybrid state caches have no seq capacity)")
    cur = cache["k"].shape[2]
    if to_len < cur:
        raise ValueError(f"grow_cache cannot shrink the cache "
                         f"({cur} -> {to_len})")
    pad = to_len - cur
    out = dict(cache)
    if pad == 0:
        return out
    for key in ("k", "v", "k_scale", "v_scale"):
        if key in cache:
            a = cache[key]
            out[key] = jnp.pad(a, ((0, 0),) * 2 + ((0, pad),)
                               + ((0, 0),) * (a.ndim - 3))
    p = cache["pos"]
    out["pos"] = jnp.pad(p, ((0, 0),) * (p.ndim - 1) + ((0, pad),),
                         constant_values=-1)
    return out


def build_serve_prefill(cfg: ModelConfig, mesh, global_batch: int,
                        seq_len: int, *, check_vma: bool = False
                        ) -> StepBundle:
    """Serve-tier prefill of ONE admission bucket at fixed shapes.

    ``bundle.fn(params, batch, prompt_len)`` -> (per-row last-REAL-token
    logits, slot-layout cache); ``prompt_len`` is (B,) int32 so shorter
    prompts right-pad into the bucket without retracing. ``fn`` is a
    TraceCounted jit: the serve tier asserts its compile-once-per-bucket
    invariant through ``utils.jit_stats``.
    """
    ctx = shard_ctx(mesh)
    cfg.validate(ctx)
    pspecs = LM.param_specs(cfg, ctx)
    params_abs = jax.eval_shape(
        lambda k: LM.init_params(cfg, ctx, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    B, S = global_batch, seq_len
    shape = InputShape(f"serve-prefill-{S}", S, B, "prefill")
    dp = _dp_spec_axis(ctx) if B % ctx.dp_size == 0 and B >= ctx.dp_size \
        else None
    batch_abs, bspecs = batch_struct(cfg, shape, ctx)
    local = LM.make_prefill_slots(cfg, ctx, B, S)
    cspecs = LM.cache_specs_slots(cfg, ctx, B, S)
    in_specs = (pspecs, bspecs, P(dp))
    out_specs = (P(dp, None), cspecs)
    gfn = _shard_map(local, mesh, in_specs, out_specs, check_vma)
    fn = trace_counted(gfn, in_shardings=_ns(mesh, in_specs),
                       out_shardings=_ns(mesh, out_specs))
    plen_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    return StepBundle("serve_prefill", fn,
                      (params_abs, batch_abs, plen_abs),
                      _ns(mesh, in_specs), _ns(mesh, out_specs), ctx, cfg,
                      shape)


def build_serve_decode(cfg: ModelConfig, mesh, n_slots: int, seq_len: int,
                       *, check_vma: bool = False) -> StepBundle:
    """Serve-tier continuous-batching decode: one compiled program at
    (n_slots, seq_len) forever; requests stream through it.

    ``bundle.fn(params, cache, token, active)`` -> (logits, cache');
    the cache is donated (ring-buffer style in-place churn). ``fn`` is a
    TraceCounted jit so the no-retrace-under-churn invariant is
    assertable.
    """
    ctx = shard_ctx(mesh)
    cfg.validate(ctx)
    pspecs = LM.param_specs(cfg, ctx)
    params_abs = jax.eval_shape(
        lambda k: LM.init_params(cfg, ctx, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    B, S = n_slots, seq_len
    shape = InputShape(f"serve-decode-{S}", S, B, "decode")
    dp = _dp_spec_axis(ctx) if B % ctx.dp_size == 0 and B >= ctx.dp_size \
        else None
    local = LM.make_decode_slots(cfg, ctx, B, S)
    cache_abs = jax.eval_shape(
        functools.partial(LM.init_cache_slots, cfg, ctx, B, S))
    cspecs = LM.cache_specs_slots(cfg, ctx, B, S)
    token_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    active_abs = jax.ShapeDtypeStruct((B,), jnp.bool_)
    in_specs = (pspecs, cspecs, P(dp, None), P(dp))
    out_specs = (P(dp, None), cspecs)
    gfn = _shard_map(local, mesh, in_specs, out_specs, check_vma)
    fn = trace_counted(gfn, in_shardings=_ns(mesh, in_specs),
                       out_shardings=_ns(mesh, out_specs),
                       donate_argnums=(1,))
    return StepBundle("serve_decode", fn,
                      (params_abs, cache_abs, token_abs, active_abs),
                      _ns(mesh, in_specs), _ns(mesh, out_specs), ctx, cfg,
                      shape)
