"""Transformer building blocks with explicit (manual) tensor parallelism.

Every forward function here is written to execute INSIDE ``jax.shard_map``
over a mesh with axes ``ctx.dp_axes + (ctx.tp_axis,)``. Arrays arriving at
these functions are the per-device *local* shards; cross-device reductions
are explicit ``psum``/``all_gather`` calls. A 1x1 mesh gives the
single-device path (collectives become no-ops), so smoke tests and the
production dry-run share one code path.

Parameter builders come in pairs: ``init_*`` produces GLOBAL parameter
pytrees (used eagerly only for small configs; the dry-run calls them under
``jax.eval_shape``), and ``spec_*`` produces the matching
``PartitionSpec`` pytree consumed by shard_map's in_specs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import ad_checkpoint
from jax.sharding import PartitionSpec as P

from repro.kernels.flash_attention import ops as attn_ops
from repro.models.config import ModelConfig, ShardCtx

# --------------------------------------------------------------------------
# helpers


def psum_tp(x, ctx: ShardCtx):
    return jax.lax.psum(x, ctx.tp_axis) if ctx.tp_size > 1 else x


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _psum_bf16_bwd(x, axis):
    return jax.lax.psum(x, axis)


def _psum_bf16_fwd_rule(x, axis):
    return jax.lax.psum(x, axis), None


def _psum_bf16_bwd_rule(axis, _, ct):
    # The backward dx reduction (Megatron's bwd all-reduce) carried in
    # bf16: halves ICI bytes vs the default f32 cotangent (§Perf iter 4).
    return (jax.lax.psum(ct.astype(jnp.bfloat16), axis).astype(ct.dtype),)


_psum_bf16_bwd.defvjp(_psum_bf16_fwd_rule, _psum_bf16_bwd_rule)


def reduce_tp(x, ctx: ShardCtx):
    """Row-parallel output reduction over tp.

    Baseline: all-reduce (psum). Options measured in §Perf:
      ctx.rs_ag            — reduce_scatter+all_gather pair (exact psum;
                             REFUTED: identical ICI bytes — see EXPERIMENTS)
      ctx.bf16_grad_reduce — custom-vjp psum whose backward reduction is
                             carried in bf16 (halves bwd dx bytes)
      (forward output is tagged for the save-collectives remat policy.)
    """
    if ctx.tp_size <= 1:
        return x
    if getattr(ctx, "rs_ag", False) and x.shape[-1] % ctx.tp_size == 0:
        s = jax.lax.psum_scatter(x, ctx.tp_axis, scatter_dimension=x.ndim - 1,
                                 tiled=True)
        out = jax.lax.all_gather(s, ctx.tp_axis, axis=x.ndim - 1, tiled=True)
    elif getattr(ctx, "bf16_grad_reduce", False):
        out = _psum_bf16_bwd(x, ctx.tp_axis)
    else:
        out = jax.lax.psum(x, ctx.tp_axis)
    # tag so the remat policy can SAVE collective outputs instead of
    # re-communicating them during the backward recompute (§Perf iter 3)
    return ad_checkpoint.checkpoint_name(out, "tp_reduce")


def pmax_tp(x, ctx: ShardCtx):
    return jax.lax.pmax(x, ctx.tp_axis) if ctx.tp_size > 1 else x


def psum_dp(x, ctx: ShardCtx):
    return jax.lax.psum(x, ctx.dp_axes) if ctx.dp_size > 1 else x


def tp_index(ctx: ShardCtx):
    if ctx.tp_size == 1:
        return jnp.zeros((), jnp.int32)
    return jax.lax.axis_index(ctx.tp_axis)


def dp_index(ctx: ShardCtx):
    if ctx.dp_size == 1:
        return jnp.zeros((), jnp.int32)
    idx = jnp.zeros((), jnp.int32)
    for ax in ctx.dp_axes:
        idx = idx * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
    return idx


def rmsnorm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _dense_init(key, shape, scale_dim, dtype):
    return (jax.random.normal(key, shape, jnp.float32)
            * (scale_dim ** -0.5)).astype(dtype)


def matmul(x, w):
    """bf16 matmul with f32 accumulation."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


# --------------------------------------------------------------------------
# local head bookkeeping


def head_layout(cfg: ModelConfig, ctx: ShardCtx):
    """Returns (H_pad, H_loc, kv_sharded, KV_loc)."""
    hp = cfg.padded_heads(ctx.tp_size)
    h_loc = hp // ctx.tp_size
    kv_sharded = cfg.num_kv_heads % ctx.tp_size == 0
    kv_loc = cfg.num_kv_heads // ctx.tp_size if kv_sharded else 1
    return hp, h_loc, kv_sharded, kv_loc


# --------------------------------------------------------------------------
# attention block


def init_attn(cfg: ModelConfig, ctx: ShardCtx, key) -> Dict[str, Any]:
    hp = cfg.padded_heads(ctx.tp_size)
    hd, d = cfg.hd, cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "ln": jnp.ones((d,), dt),
        "wq": _dense_init(ks[0], (d, hp * hd), d, dt),
        "wk": _dense_init(ks[1], (d, cfg.num_kv_heads * hd), d, dt),
        "wv": _dense_init(ks[2], (d, cfg.num_kv_heads * hd), d, dt),
        "wo": _dense_init(ks[3], (hp * hd, d), hp * hd, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def spec_attn(cfg: ModelConfig, ctx: ShardCtx) -> Dict[str, Any]:
    tp = ctx.tp_axis
    kv_sharded = cfg.num_kv_heads % ctx.tp_size == 0
    kv = P(None, tp) if kv_sharded else P(None, None)
    p = {"ln": P(None), "wq": P(None, tp), "wk": kv, "wv": kv,
         "wo": P(tp, None)}
    if cfg.qk_norm:
        p["q_norm"] = P(None)
        p["k_norm"] = P(None)
    return p


def _qkv(cfg: ModelConfig, ctx: ShardCtx, p, h, positions):
    """h: (B, S, d) -> q (B,S,H_loc,hd), k/v (B,S,KV_loc,hd), roped."""
    hp, h_loc, kv_sharded, kv_loc = head_layout(cfg, ctx)
    hd = cfg.hd
    B, S, _ = h.shape
    q = matmul(h, p["wq"]).reshape(B, S, h_loc, hd)
    k = matmul(h, p["wk"]).reshape(B, S, -1, hd)
    v = matmul(h, p["wv"]).reshape(B, S, -1, hd)
    if not kv_sharded:
        # replicated kv: pick the single kv head this shard's q heads use
        g = hp // cfg.num_kv_heads
        kv_head = (tp_index(ctx) * h_loc) // g
        kv_head = jnp.minimum(kv_head, cfg.num_kv_heads - 1)
        k = jax.lax.dynamic_slice_in_dim(k, kv_head, 1, axis=2)
        v = jax.lax.dynamic_slice_in_dim(v, kv_head, 1, axis=2)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_forward(cfg: ModelConfig, ctx: ShardCtx, p, x, positions, *,
                 causal: bool = True, return_kv: bool = False):
    """Full-sequence attention (train / prefill). x: (B, S, d) local."""
    h = rmsnorm(x, p["ln"])
    q, k, v = _qkv(cfg, ctx, p, h, positions)
    o = attn_ops.attention(q, k, v, causal=causal, window=cfg.attn_window)
    B, S = x.shape[:2]
    o = matmul(o.reshape(B, S, -1), p["wo"])
    o = reduce_tp(o, ctx)
    out = x + o
    if return_kv:
        return out, (k, v)
    return out


def decode_mode(cfg: ModelConfig, ctx: ShardCtx, global_batch: int,
                seq_len: int):
    """Statically pick the KV-cache layout. Returns a dict of
    {kind, seq_axes, batch_dp, s_cache} (see module docstring in lm.py).

      kind "W": sliding-window ring cache, replicated over tp.
      kind "A": kv heads sharded over tp (requires KV % tp == 0);
                seq optionally sharded over dp when batch is not.
      kind "B": seq sharded over tp (for KV < tp); q heads all-gathered;
                flash-decode logsumexp combine over tp (and dp if seq-dp).
    """
    window = cfg.attn_window
    batch_dp = global_batch % ctx.dp_size == 0 and global_batch >= ctx.dp_size
    if window and window > 0:
        s_cache = min(window, seq_len + 1)
        return dict(kind="W", seq_axes=(), batch_dp=batch_dp, s_cache=s_cache)
    seq_dp = not batch_dp
    if cfg.num_kv_heads % ctx.tp_size == 0:
        seq_axes = ctx.dp_axes if seq_dp else ()
        kind = "A"
    else:
        seq_axes = (ctx.dp_axes + (ctx.tp_axis,)) if seq_dp \
            else (ctx.tp_axis,)
        kind = "B"
    n = axes_size(ctx, seq_axes) if seq_axes else 1
    s_cache = -((seq_len + 1) // -n) * n  # pad so the shards divide evenly
    return dict(kind=kind, seq_axes=seq_axes, batch_dp=batch_dp,
                s_cache=s_cache)


# --------------------------------------------------------------------------
# int8 KV quantisation (§Perf decode memory iteration): absmax per
# (slot, head) vector; halves cache HBM traffic at decode.


def kv_quantize(x):
    """x: (..., hd) bf16 -> (int8 values, f32 scale[..., 1])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def kv_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def axes_size(ctx: ShardCtx, axes) -> int:
    n = 1
    for ax in axes:
        n *= ctx.tp_size if ax == ctx.tp_axis else 1
    dp_in = [ax for ax in axes if ax != ctx.tp_axis]
    if dp_in:
        if tuple(dp_in) != tuple(ctx.dp_axes):
            raise ValueError("seq_axes must use all dp axes or none")
        n *= ctx.dp_size
    return n


def _axes_index(ctx: ShardCtx, axes):
    idx = jnp.zeros((), jnp.int32)
    for ax in axes:
        idx = idx * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
    return idx


def attn_decode(cfg: ModelConfig, ctx: ShardCtx, p, x, k_cache, v_cache,
                cache_pos, index, mode, k_scale=None, v_scale=None):
    """Single-token decode under the layout in ``mode``.

    x: (B_loc, 1, d); caches: (B_loc, S_loc, KV_loc, hd);
    cache_pos: (S_loc,) global position per slot (-1 empty); index: scalar
    number of tokens already in sequence. Returns (out, k, v, pos) — plus
    (k_scale, v_scale) when the cache is int8-quantised (scales shaped
    (B, S_loc, KV_loc, 1), ctx.kv_int8 / §Perf decode-memory iteration).
    """
    quant = k_scale is not None
    B = x.shape[0]
    kind = mode["kind"]
    hp, h_loc, kv_sharded, kv_loc = head_layout(cfg, ctx)
    h = rmsnorm(x, p["ln"])
    hd = cfg.hd
    q = matmul(h, p["wq"]).reshape(B, 1, h_loc, hd)
    k = matmul(h, p["wk"]).reshape(B, 1, -1, hd)
    v = matmul(h, p["wv"]).reshape(B, 1, -1, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = rope(q, index[None], cfg.rope_theta)
    k = rope(k, index[None], cfg.rope_theta)

    S_loc = k_cache.shape[1]
    window = cfg.attn_window
    slot = index % S_loc if kind == "W" else index

    # Cache-resident kv layout: kind A+replicated-wk needs the local slice;
    # kinds W and B keep the FULL kv heads in the cache (replicated wk).
    if kind == "A" and not kv_sharded:
        g = hp // cfg.num_kv_heads
        kvh = jnp.minimum((tp_index(ctx) * h_loc) // g, cfg.num_kv_heads - 1)
        k = jax.lax.dynamic_slice_in_dim(k, kvh, 1, axis=2)
        v = jax.lax.dynamic_slice_in_dim(v, kvh, 1, axis=2)

    if quant:
        kq, ks = kv_quantize(k)
        vq, vs = kv_quantize(v)
    seq_axes = mode["seq_axes"]
    if seq_axes:
        start = _axes_index(ctx, seq_axes) * S_loc
        local = slot - start
        owns = (local >= 0) & (local < S_loc)
        lc = jnp.clip(local, 0, S_loc - 1)
        def upd(c, val, ax=1):
            new_c = jax.lax.dynamic_update_slice_in_dim(c, val, lc, axis=ax)
            return jnp.where(owns, new_c, c)
        if quant:
            k_cache, v_cache = upd(k_cache, kq), upd(v_cache, vq)
            k_scale, v_scale = upd(k_scale, ks), upd(v_scale, vs)
        else:
            k_cache, v_cache = upd(k_cache, k), upd(v_cache, v)
        cache_pos = jnp.where(owns, jax.lax.dynamic_update_slice_in_dim(
            cache_pos, index[None], lc, axis=0), cache_pos)
    else:
        if quant:
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, kq, slot,
                                                          axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, vq, slot,
                                                          axis=1)
            k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks, slot,
                                                          axis=1)
            v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs, slot,
                                                          axis=1)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot,
                                                          axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot,
                                                          axis=1)
        cache_pos = jax.lax.dynamic_update_slice_in_dim(
            cache_pos, index[None], slot, axis=0)
    if quant:
        k_att = kv_dequantize(k_cache, k_scale, x.dtype)
        v_att = kv_dequantize(v_cache, v_scale, x.dtype)
    else:
        k_att, v_att = k_cache, v_cache

    valid = (cache_pos >= 0) & (cache_pos <= index)
    if window and window > 0:
        valid &= cache_pos > (index - window)

    if kind == "B":
        # all q heads attend each shard's seq chunk; combine across shards
        q_full = q
        if ctx.tp_size > 1:
            q_full = jax.lax.all_gather(q, ctx.tp_axis, axis=2, tiled=True)
        o_w, lse = _masked_decode(q_full[:, 0], k_att, v_att, valid)
    elif kind == "W" and not kv_sharded:
        # cache holds ALL kv heads; slice the one this shard's q heads use
        g = hp // cfg.num_kv_heads
        kvh = jnp.minimum((tp_index(ctx) * h_loc) // g, cfg.num_kv_heads - 1)
        kc = jax.lax.dynamic_slice_in_dim(k_att, kvh, 1, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(v_att, kvh, 1, axis=2)
        o_w, lse = _masked_decode(q[:, 0], kc, vc, valid)
    else:
        o_w, lse = _masked_decode(q[:, 0], k_att, v_att, valid)

    if seq_axes:
        m = jax.lax.pmax(lse, seq_axes)
        w = jnp.exp(lse - m)
        o_sum = jax.lax.psum(o_w * w[..., None], seq_axes)
        d_sum = jax.lax.psum(w, seq_axes)
        o = o_sum / jnp.maximum(d_sum[..., None], 1e-30)
    else:
        o = o_w

    if kind == "B":
        # slice back this shard's q heads for the row-parallel wo
        o = jax.lax.dynamic_slice_in_dim(o, tp_index(ctx) * h_loc, h_loc,
                                         axis=1)
    o = matmul(o.reshape(B, 1, -1).astype(x.dtype), p["wo"])
    o = psum_tp(o, ctx)
    if quant:
        return x + o, k_cache, v_cache, cache_pos, k_scale, v_scale
    return x + o, k_cache, v_cache, cache_pos


def _masked_decode(q, k_cache, v_cache, valid):
    """q: (B, Hq, hd); caches (B, S, KV, hd); valid: (S,) bool shared
    across the batch (lock-step decode) or (B, S) per-row (slot decode).

    Returns locally-normalised output and the local logsumexp.
    """
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf,
                   k_cache.astype(jnp.float32)) * (D ** -0.5)
    mask = valid[None, None, None] if valid.ndim == 1 \
        else valid[:, None, None]
    s = jnp.where(mask, s, -1e30)
    m = s.max(-1)
    pexp = jnp.exp(s - m[..., None])
    den = pexp.sum(-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", pexp, v_cache.astype(jnp.float32))
    lse = m + jnp.log(jnp.maximum(den, 1e-30))
    o = o / jnp.maximum(den[..., None], 1e-30)
    return o.reshape(B, Hq, D), lse.reshape(B, Hq)


def attn_decode_slots(cfg: ModelConfig, ctx: ShardCtx, p, x, k_cache,
                      v_cache, cache_pos, index, active, mode):
    """Per-SLOT single-token decode for the continuous-batching serve tier.

    Unlike :func:`attn_decode` (one scalar ``index`` marching the whole
    batch in lock step) every batch row here is an independent request:
    ``index`` is ``(B,)`` per-row token counts, ``cache_pos`` is
    ``(B, S_loc)`` and ``active`` is a ``(B,)`` bool mask. Inactive rows
    scatter to an out-of-range target that ``mode="drop"`` discards (the
    ReplayBuffer ring-write idiom), so retired/empty slots cost compute
    but can never corrupt cache state. Layout support is deliberately the
    serve subset: kind "A", unsharded sequence axis, fp KV, no window.
    """
    if mode["seq_axes"]:
        raise ValueError("attn_decode_slots: sequence-sharded KV caches "
                         "are not supported")
    if mode["kind"] != "A":
        raise ValueError("attn_decode_slots: unsupported cache layout "
                         f"kind {mode['kind']!r} (need 'A')")
    B = x.shape[0]
    hp, h_loc, kv_sharded, kv_loc = head_layout(cfg, ctx)
    h = rmsnorm(x, p["ln"])
    hd = cfg.hd
    q = matmul(h, p["wq"]).reshape(B, 1, h_loc, hd)
    k = matmul(h, p["wk"]).reshape(B, 1, -1, hd)
    v = matmul(h, p["wv"]).reshape(B, 1, -1, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    pos_b = index[:, None]  # (B, 1): each row rotates at its own position
    q = rope(q, pos_b, cfg.rope_theta)
    k = rope(k, pos_b, cfg.rope_theta)

    if not kv_sharded:
        g = hp // cfg.num_kv_heads
        kvh = jnp.minimum((tp_index(ctx) * h_loc) // g, cfg.num_kv_heads - 1)
        k = jax.lax.dynamic_slice_in_dim(k, kvh, 1, axis=2)
        v = jax.lax.dynamic_slice_in_dim(v, kvh, 1, axis=2)

    S_loc = k_cache.shape[1]
    row = jnp.arange(B)
    tgt = jnp.where(active, index, S_loc)
    k_cache = k_cache.at[row, tgt].set(k[:, 0], mode="drop")
    v_cache = v_cache.at[row, tgt].set(v[:, 0], mode="drop")
    cache_pos = cache_pos.at[row, tgt].set(index, mode="drop")

    valid = (cache_pos >= 0) & (cache_pos <= index[:, None])  # (B, S_loc)
    o, _ = _masked_decode(q[:, 0], k_cache, v_cache, valid)
    o = matmul(o.reshape(B, 1, -1).astype(x.dtype), p["wo"])
    o = psum_tp(o, ctx)
    return x + o, k_cache, v_cache, cache_pos


# --------------------------------------------------------------------------
# dense MLP (SwiGLU / GELU)


def init_mlp(cfg: ModelConfig, ctx: ShardCtx, key):
    d, f = cfg.d_model, cfg.padded_ff(ctx.tp_size)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"ln": jnp.ones((d,), dt),
         "w1": _dense_init(ks[0], (d, f), d, dt),
         "w2": _dense_init(ks[1], (f, d), f, dt)}
    if cfg.mlp_type == "swiglu":
        p["w3"] = _dense_init(ks[2], (d, f), d, dt)
    return p


def spec_mlp(cfg: ModelConfig, ctx: ShardCtx):
    tp = ctx.tp_axis
    p = {"ln": P(None), "w1": P(None, tp), "w2": P(tp, None)}
    if cfg.mlp_type == "swiglu":
        p["w3"] = P(None, tp)
    return p


def mlp_forward(cfg: ModelConfig, ctx: ShardCtx, p, x):
    h = rmsnorm(x, p["ln"])
    a = matmul(h, p["w1"])
    if cfg.mlp_type == "swiglu":
        a = jax.nn.silu(a.astype(jnp.float32)).astype(x.dtype) * matmul(h, p["w3"])
    else:
        a = jax.nn.gelu(a.astype(jnp.float32)).astype(x.dtype)
    o = matmul(a, p["w2"])
    o = reduce_tp(o, ctx)
    return x + o


# --------------------------------------------------------------------------
# embedding / unembedding / loss (vocab-sharded)


def init_embed(cfg: ModelConfig, ctx: ShardCtx, key):
    vp, d = cfg.padded_vocab(ctx.tp_size), cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    return {"table": _dense_init(k1, (vp, d), d, dt),
            "head": _dense_init(k2, (d, vp), d, dt),
            "ln_f": jnp.ones((d,), dt)}


def spec_embed(cfg: ModelConfig, ctx: ShardCtx):
    tp = ctx.tp_axis
    return {"table": P(tp, None), "head": P(None, tp), "ln_f": P(None)}


def embed_tokens(cfg: ModelConfig, ctx: ShardCtx, p, tokens):
    """tokens: (B, S) int32 local batch. Vocab-sharded lookup + psum."""
    v_loc = p["table"].shape[0]
    offset = tp_index(ctx) * v_loc
    local = tokens - offset
    ok = (local >= 0) & (local < v_loc)
    local = jnp.clip(local, 0, v_loc - 1)
    e = jnp.take(p["table"], local, axis=0)
    e = jnp.where(ok[..., None], e, 0)
    return psum_tp(e, ctx)


def lm_loss(cfg: ModelConfig, ctx: ShardCtx, p, h, labels, *,
            chunk_tokens: int = 2048):
    """Sharded-vocab softmax cross-entropy, chunked over tokens.

    h: (B, S, d) local; labels: (B, S) int32 (-1 = ignore).
    Returns (sum_loss_local, count_local) — caller psums over dp.
    """
    d = h.shape[-1]
    h = rmsnorm(h, p["ln_f"])
    T = h.shape[0] * h.shape[1]
    hf = h.reshape(T, d)
    lf = labels.reshape(T)
    v_loc = p["head"].shape[1]
    offset = tp_index(ctx) * v_loc
    n_real_here = jnp.clip(cfg.vocab_size - offset, 0, v_loc)
    col_valid = jnp.arange(v_loc) < n_real_here

    chunk = min(chunk_tokens, T)
    pad = (-T) % chunk
    hf = jnp.pad(hf, ((0, pad), (0, 0)))
    lf = jnp.pad(lf, (0, pad), constant_values=-1)
    nch = hf.shape[0] // chunk

    def per_chunk(args):
        hc, lc = args
        logits = jnp.dot(hc, p["head"],
                         preferred_element_type=jnp.float32)
        logits = jnp.where(col_valid[None, :], logits, -1e30)
        # max-subtraction is gradient-free (standard logsumexp stabilisation);
        # stop_gradient BEFORE the pmax so the collective sees a symbolic-zero
        # tangent (pmax has no differentiation rule).
        m = pmax_tp(jax.lax.stop_gradient(logits.max(-1)), ctx)
        se = psum_tp(jnp.exp(logits - m[:, None]).sum(-1), ctx)
        lse = m + jnp.log(jnp.maximum(se, 1e-30))
        lab_loc = lc - offset
        hit = (lab_loc >= 0) & (lab_loc < v_loc)
        lab_logit = jnp.take_along_axis(
            logits, jnp.clip(lab_loc, 0, v_loc - 1)[:, None], axis=1)[:, 0]
        lab_logit = psum_tp(jnp.where(hit, lab_logit, 0.0), ctx)
        keep = lc >= 0
        loss = jnp.where(keep, lse - lab_logit, 0.0)
        return loss.sum(), keep.sum()

    losses, counts = jax.lax.map(
        per_chunk, (hf.reshape(nch, chunk, d), lf.reshape(nch, chunk)))
    return losses.sum(), counts.sum()


def lm_logits_last(cfg: ModelConfig, ctx: ShardCtx, p, h_last):
    """h_last: (B, d) -> full-vocab logits (B, V_pad) gathered over tp."""
    h = rmsnorm(h_last, p["ln_f"])
    logits = jnp.dot(h, p["head"], preferred_element_type=jnp.float32)
    if ctx.tp_size > 1:
        logits = jax.lax.all_gather(logits, ctx.tp_axis, axis=1, tiled=True)
    return logits
