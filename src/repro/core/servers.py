"""The three servers of Figure 1a.

Workers communicate EXCLUSIVELY through these: a data buffer server and
two parameter servers (model, policy). Thread-safe, versioned; ``pull``
never blocks on a writer (the paper's lock-free spirit at phase
granularity — see DESIGN.md §2 for the TPU adaptation).
"""
from __future__ import annotations

import threading
from typing import Any, List, Optional

import jax
import numpy as np


class ParameterServer:
    """Versioned pytree store (Alg. 1/2/3 'Pull/Push parameters')."""

    def __init__(self, initial=None):
        self._lock = threading.Lock()
        self._value = initial
        self._version = 0 if initial is None else 1

    def push(self, value) -> int:
        # device->host copy outside the lock; keep the critical section tiny
        host = jax.tree.map(np.asarray, value)
        with self._lock:
            self._value = host
            self._version += 1
            return self._version

    def pull(self):
        """Returns (value, version); value is None until the first push."""
        with self._lock:
            return self._value, self._version

    @property
    def version(self) -> int:
        with self._lock:
            return self._version


class DataServer:
    """FIFO trajectory buffer server (Alg. 1 'Push data', Alg. 2 line 3:
    'move all trajectories from the remote buffer')."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items: List[Any] = []
        self._total = 0

    def push(self, traj) -> int:
        host = jax.tree.map(np.asarray, traj)
        with self._lock:
            self._items.append(host)
            self._total += 1
            return self._total

    def drain(self) -> List[Any]:
        """Move ALL pending trajectories to the caller (empties server)."""
        with self._lock:
            items, self._items = self._items, []
            return items

    @property
    def total_pushed(self) -> int:
        with self._lock:
            return self._total

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)


class LocalBuffer:
    """Fixed-size FIFO local buffer with a held-out validation split
    (Alg. 2: model learner trains on its LOCAL buffer; §4 'The local
    buffer is of fixed size and first-in-first-out')."""

    def __init__(self, max_trajs: int = 200, holdout_frac: float = 0.2):
        self.max_trajs = max_trajs
        self.holdout_frac = holdout_frac
        self._train: List[Any] = []
        self._val: List[Any] = []
        self._count = 0

    def extend(self, trajs) -> int:
        for t in trajs:
            self._count += 1
            # deterministic interleave keeps val non-empty and ~frac
            if self.holdout_frac > 0 and \
                    self._count % max(int(round(1 / self.holdout_frac)), 2) == 0:
                self._val.append(t)
                if len(self._val) > max(self.max_trajs // 4, 1):
                    self._val.pop(0)
            else:
                self._train.append(t)
                if len(self._train) > self.max_trajs:
                    self._train.pop(0)
        return len(trajs)

    def _stack(self, items):
        if not items:
            return None
        cat = {k: np.concatenate([t[k] for t in items], axis=0)
               for k in items[0]}
        return cat

    def train_arrays(self):
        return self._stack(self._train)

    def val_arrays(self):
        return self._stack(self._val if self._val else self._train[-1:])

    @property
    def n_train(self):
        return len(self._train)

    @property
    def total_seen(self):
        return self._count
