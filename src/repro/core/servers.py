"""The three servers of Figure 1a.

Workers communicate EXCLUSIVELY through these: a data buffer server and
two parameter servers (model, policy). Thread-safe, versioned; ``pull``
never blocks on a writer (the paper's lock-free spirit at phase
granularity — see DESIGN.md §2 for the TPU adaptation).

Three transport families share one interface — the :class:`ParameterTransport`
/ :class:`DataTransport` protocols below (PR 9), so workers, engines, and
supervisors are transport-blind:

* in-process (``ParameterServer`` / ``DataServer``): device-resident,
  zero-copy — the event and threads engines;
* cross-process (``ShmParameterServer`` / ``ProcDataServer``): the
  ``mode="procs"`` engine. Parameters live in a posix shared-memory
  segment serialised with the flat-key codec from ``checkpoint/io.py``
  (never pickled per-pull); trajectories ride a ``multiprocessing``
  queue into the model worker's ring buffer. The PR 1 version contract
  is preserved: ``push`` bumps an atomic version, ``pull_if_newer`` on
  an unchanged version is ONE 8-byte read — zero array copies
  (counter-instrumented; asserted by tests/test_procs.py);
* cross-host (``repro.net``: ``TcpParameterServer`` / ``TcpDataServer``
  against a ``ControlPlane``): ``RunConfig.transport="tcp"``. The
  version word rides the 32-byte frame header, so an unchanged
  ``pull_if_newer`` moves ZERO array bytes over the wire; the ticket
  counters live on the plane, so the exact criterion and crash-refund
  semantics hold verbatim across hosts. See docs/WIRE_PROTOCOL.md.

Both data servers are MULTI-PRODUCER (collector fleets, ISSUE 5): N
collectors push concurrently, the global trajectory counter stays exact
under interleaved pushes and collector restarts, and the stopping
criterion is ticket-based (``try_claim``) so a fleet can never overshoot
``total_trajs``. The model worker's drain batches a burst of M
trajectories into ONE compile-once padded scatter
(``ReplayBuffer.add_trajs``) instead of M sequential ring writes.

Hot-path invariants (see benchmarks/hotpath.py, which enforces them):

* ``ParameterServer`` keeps values DEVICE-RESIDENT. ``push``/``pull``
  never round-trip through the host; ``pull_host`` exists only for
  checkpoint / serving boundaries.
* ``ParameterServer.pull_if_newer(version)`` costs one lock + integer
  compare when the version is unchanged — no pytree traversal, no copy.
* ``ReplayBuffer`` is a preallocated fixed-capacity ring of static-shape
  arrays: no per-epoch ``np.concatenate``, no growing shapes, so a
  trainer compiled against ``train_view()`` never retraces.
"""
from __future__ import annotations

import queue as _queue
import struct
import threading
import time
from typing import (Any, Dict, List, Optional, Protocol, Tuple,
                    runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

# NOTE: on backends without buffer aliasing (CPU) the donated jits below
# warn once at compile that donation fell back to a copy — that is
# expected there and left visible on purpose (no global warning filter).


# ------------------------------------------------------------ transport seam
#
# The PR 9 pluggable-transport contract. These protocols are DOCUMENTED
# interfaces, not base classes: the three implementations (in-process,
# shm/mp, tcp) share no code — each earns the guarantees its own way —
# and `isinstance(x, ParameterTransport)` checks the seam structurally.

@runtime_checkable
class ParameterTransport(Protocol):
    """What every parameter store guarantees, whatever the wire.

    * ``push(value) -> version``: publish atomically; a reader can never
      observe a torn value (device snapshot / seqlock / server-side swap
      under one lock). Monotone: each push bumps the version by 1.
    * ``pull_if_newer(version, *, sharding=None) -> (value|None, ver)``:
      the UNCHANGED path transfers no array data — one int compare
      (in-process), one 8-byte shm read, or one header-only TCP
      round-trip — and is counter-asserted by tests and benchmarks.
    * ``pull() -> (value|None, version)``: unconditional latest.
    * ``pull_host() -> (host value|None, version)``: the only sanctioned
      device->host boundary (checkpoint / serving / supervisor).
    * ``version -> int``: current version; 0 means nothing pushed yet.
    * crash safety: a writer dying mid-push never corrupts what readers
      see — they keep their cached value (degrade, never hang or tear).
    """

    def push(self, value) -> int: ...
    def pull(self): ...
    def pull_if_newer(self, version: int, *, sharding=None): ...
    def pull_host(self): ...
    @property
    def version(self) -> int: ...


@runtime_checkable
class DataTransport(Protocol):
    """What every trajectory data server guarantees, whatever the wire.

    * ``push(traj, *, collector_id)`` / ``push_batch(batch, n, *,
      collector_id)``: multi-producer safe; ``total_pushed`` moves
      atomically with the pusher's in-flight settlement (one lock), so
      the global count is exact under interleaving and restarts.
    * ``try_claim(collector_id, k) -> granted``: reserves
      ``min(k, remaining)`` toward the armed target under that same
      lock — a fleet can never overshoot; denied claims back off
      ``claim_backoff`` seconds instead of spinning.
    * ``refund_inflight(collector_id) -> n``: returns EXACTLY the
      tickets claimed-but-never-pushed by a dead collector; idempotent.
    * ``drain() -> [traj dict, ...]``: moves everything queued to the
      caller; batch items are unstacked into per-lane dicts.
    * ``set_target(total)`` arms the criterion; ``total_pushed`` /
      ``__len__`` report exact global progress.
    * backpressure: a push against a full bounded queue raises
      :class:`BackpressureError` after ``push_timeout`` — loud, never a
      silent drop (the unbounded in-process server never blocks).
    """

    def push(self, traj, *, collector_id: int = 0) -> int: ...
    def push_batch(self, batch, n: int, *, collector_id: int = 0) -> int: ...
    def set_target(self, total: int) -> None: ...
    def try_claim(self, collector_id: int = 0, k: int = 1) -> int: ...
    def refund_inflight(self, collector_id: int) -> int: ...
    def drain(self) -> List[Any]: ...
    @property
    def total_pushed(self) -> int: ...
    def __len__(self) -> int: ...


class ParameterServer:
    """Versioned pytree store (Alg. 1/2/3 'Pull/Push parameters').

    Values stay on device. ``push`` snapshots leaves with a device-side
    copy so published versions are isolated from training buffers that
    the pusher later donates back into its jitted update step.

    Placement-aware (role meshes, core/roles.py): ``push`` records the
    source sharding; ``pull_if_newer(version, sharding=...)`` moves the
    value onto the puller's sub-mesh with an explicit device-to-device
    ``device_put`` — only on a version change, and only when the source
    placement differs. The unchanged path stays one lock + int compare.
    """

    def __init__(self, initial=None):
        self._lock = threading.Lock()
        # snapshot like push(): the stored version must stay isolated
        # from buffers the caller may later donate into a jit
        self._value = None if initial is None else self._snapshot(initial)
        self._version = 0 if initial is None else 1
        self._src_sharding = (None if self._value is None
                              else self._leaf_sharding(self._value))

    @staticmethod
    def _snapshot(value):
        # device->device copy (cheap); NOT a host transfer. Isolates the
        # stored version from donate_argnums buffer reuse by the pusher.
        return jax.tree.map(jnp.copy, value)

    @staticmethod
    def _leaf_sharding(value):
        """Sharding of the pushed pytree (first jax leaf; one pytree holds
        one role's params, so leaves share a placement)."""
        for leaf in jax.tree.leaves(value):
            s = getattr(leaf, "sharding", None)
            if s is not None:
                return s
        return None

    def push(self, value) -> int:
        snap = self._snapshot(value)    # copy outside the lock
        src = self._leaf_sharding(snap)
        with self._lock:
            self._value = snap
            self._src_sharding = src
            self._version += 1
            return self._version

    def pull(self):
        """Returns (value, version); value is None until the first push."""
        with self._lock:
            return self._value, self._version

    def pull_if_newer(self, version: int, *, sharding=None):
        """Version-gated pull: returns (value, current_version) when the
        server holds something newer than ``version``, else
        (None, current_version). The unchanged path is one lock + int
        compare — no copies, no pytree traversal (and therefore no
        transfer of any kind: it passes jax.transfer_guard('disallow')).

        ``sharding``: the puller's target placement (e.g. params
        replicated over its role sub-mesh). Applied only on a version
        change, and skipped when the pusher already produced that
        placement — cross-role movement is a device-to-device
        ``device_put``, never a host round-trip."""
        with self._lock:
            if self._version == version or self._value is None:
                return None, self._version
            value, ver, src = self._value, self._version, self._src_sharding
        if sharding is not None and src != sharding:
            # outside the lock: value is an immutable snapshot; one
            # pytree-aware device_put batches all leaf transfers
            value = jax.device_put(value, sharding)
        return value, ver

    def pull_host(self):
        """Host-materialised pull for checkpoint / serving boundaries —
        the ONLY place a device->host copy of the store is allowed."""
        with self._lock:
            value, version = self._value, self._version
        if value is None:
            return None, version
        return jax.tree.map(np.asarray, value), version

    @property
    def version(self) -> int:
        with self._lock:
            return self._version


class DataServer:
    """FIFO trajectory buffer server (Alg. 1 'Push data', Alg. 2 line 3:
    'move all trajectories from the remote buffer').

    Explicitly MULTI-PRODUCER (collector fleets, ISSUE 5): any number of
    collectors push concurrently; one lock makes ``total_pushed`` exact
    under interleaved pushes. The global stopping criterion is enforced
    with a ticket counter: ``set_target(n)`` arms it and ``try_claim(k)``
    hands out at most ``n - total_pushed_at_arm_time`` collection slots,
    so a fleet finishes with ``total_pushed == n`` EXACTLY — never an
    overshoot from two collectors racing past the threshold. Batch-aware
    (env farms, ISSUE 6): ``try_claim(k=B)`` grants 0..B tickets under
    the one lock — ``min(B, remaining)`` — so a farm's last batch shrinks
    to land the criterion exactly; a denied claim sleeps
    ``claim_backoff`` seconds before returning so collectors that lose
    the race near the criterion don't spin-poll at full speed.

    Zero-copy: pushed trajectories are stored by reference (jax arrays
    are immutable, so handing them across threads is safe) — no
    device->host materialisation on the hot path; a pushed BATCH is
    unstacked into per-lane slices (lazy jax views, no copies)."""

    def __init__(self, *, claim_backoff: float = 0.002):
        self.claim_backoff = float(claim_backoff)
        self._lock = threading.Lock()
        self._items: List[Any] = []
        self._total = 0
        self._target: Optional[int] = None
        self._tickets = 0
        self._inflight: Dict[int, int] = {}

    def push(self, traj, *, collector_id: int = 0) -> int:
        with self._lock:
            self._items.append(traj)
            self._total += 1
            self._dec_inflight(collector_id, 1)
            return self._total

    def push_batch(self, batch, n: int, *, collector_id: int = 0) -> int:
        """Push ``n`` trajectories stacked as one batch (dict of
        (n, H, ...) arrays — a farm step's output). Consumers always see
        per-trajectory dicts: the batch is unstacked into lane slices
        OUTSIDE the lock, then appended and counted atomically, so
        ``total_pushed`` moves by n in one step and interleaved
        producers stay exact."""
        lanes = [{k: v[i] for k, v in batch.items()} for i in range(n)]
        with self._lock:
            self._items.extend(lanes)
            self._total += n
            self._dec_inflight(collector_id, n)
            return self._total

    def set_target(self, total: int) -> None:
        """Arm the stopping criterion: from now on ``try_claim`` grants
        exactly ``total - total_pushed`` more collection slots."""
        with self._lock:
            self._target = int(total)
            self._tickets = self._total

    def try_claim(self, collector_id: int = 0, k: int = 1) -> int:
        """Reserve up to ``k`` collection slots toward the armed target;
        marks them in-flight for ``collector_id`` until the matching
        push lands. Returns the number granted — ``min(k, remaining)``,
        possibly 0 once the target is fully claimed (the collector
        should stop). No target configured: always grants ``k``. The
        denied path sleeps ``claim_backoff`` (outside the lock) so
        losers of the final-claim race back off instead of spinning."""
        k = int(k)
        with self._lock:
            g = k if self._target is None else \
                min(k, max(self._target - self._tickets, 0))
            if g > 0:
                self._tickets += g
                self._inflight[collector_id] = \
                    self._inflight.get(collector_id, 0) + g
                return g
        time.sleep(self.claim_backoff)
        return 0

    def refund_inflight(self, collector_id: int) -> int:
        """Return every ticket ``collector_id`` claimed but never
        pushed (its collector died mid-batch). Returns the number
        refunded. Mirror of :meth:`ProcDataServer.refund_inflight` for
        supervisors of in-process fleets."""
        with self._lock:
            g = self._inflight.pop(collector_id, 0)
            self._tickets -= g
            return g

    def _dec_inflight(self, collector_id: int, n: int) -> None:
        # already holding self._lock. Claims are optional (the event
        # engine pushes without claiming), so clamp at zero.
        left = self._inflight.get(collector_id, 0) - n
        if left > 0:
            self._inflight[collector_id] = left
        else:
            self._inflight.pop(collector_id, None)

    def drain(self) -> List[Any]:
        """Move ALL pending trajectories to the caller (empties server)."""
        with self._lock:
            items, self._items = self._items, []
            return items

    @property
    def total_pushed(self) -> int:
        with self._lock:
            return self._total

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)


# ----------------------------------------------------------------- procs IPC
#
# Cross-process equivalents for mode="procs" (runtime._run_procs). The
# parent creates them before spawning workers; the handles are picklable
# through multiprocessing's spawn machinery and re-attach lazily in each
# child. See ROADMAP.md "Process-isolation invariants (PR 4)".

_SHM_HEADER = 64            # [0:8) seqlock, [8:16) version, rest reserved
_SHM_ALIGN = 64             # leaf payloads start cache-line aligned

# ---- auditable lifetime registries (chaos/soak, PR 7) ----------------
# Every IPC resource this PROCESS creates (shm segments it owns, data
# servers it constructed) is registered at birth and unregistered by
# close(), so a resource auditor can prove "zero leaks" by asserting the
# registries are empty after shutdown — and a supervisor's last-resort
# cleanup can reclaim stragglers without knowing who made them.
_REGISTRY_LOCK = threading.Lock()
_SHM_REGISTRY: Dict[str, "ShmParameterServer"] = {}
_DATA_REGISTRY: Dict[int, "ProcDataServer"] = {}


def live_shm_segments() -> Tuple[str, ...]:
    """Names of posix shm segments created by this process and not yet
    closed/unlinked. Empty after every clean or chaotic shutdown."""
    with _REGISTRY_LOCK:
        return tuple(sorted(_SHM_REGISTRY))


def live_data_servers() -> int:
    """Count of ProcDataServers constructed by this process whose
    ``close()`` has not run yet."""
    with _REGISTRY_LOCK:
        return len(_DATA_REGISTRY)


def reclaim_ipc_resources() -> int:
    """Guaranteed-reclaim path: close every still-registered shm segment
    and data server created by this process. Returns how many resources
    were reclaimed. Safe to call repeatedly; normal shutdown (context
    managers / runtime ExitStack) leaves nothing for it to do."""
    with _REGISTRY_LOCK:
        stragglers = list(_SHM_REGISTRY.values()) + \
            list(_DATA_REGISTRY.values())
    for res in stragglers:
        try:
            res.close()
        except Exception:
            pass
    return len(stragglers)


def _attach_shm(name):
    """Attach (never create) an existing segment WITHOUT handing its
    lifetime to this process's resource tracker.

    Python < 3.13 registers POSIX shm with the tracker on ATTACH too
    (bpo-39959): harmless for mp-spawned workers (they inherit the
    creator's tracker, whose bookkeeping the creator's ``unlink``
    balances), but a standalone attacher — e.g. a tool unpickling a
    server handle — starts its OWN tracker, which would unlink the live
    segment when that process exits. So: prefer ``track=False``
    (3.13+); otherwise unregister ONLY when the attach just started a
    fresh tracker, i.e. this process is a standalone attacher (an
    inherited-tracker unregister would instead erase the creator's
    registration and spray KeyErrors at unlink time)."""
    from multiprocessing import shared_memory
    try:
        return shared_memory.SharedMemory(name=name, track=False)
    except TypeError:
        pass
    try:
        from multiprocessing import resource_tracker
        had_tracker = getattr(resource_tracker._resource_tracker,
                              "_fd", None) is not None
    except Exception:
        had_tracker = True      # can't tell: don't touch the tracker
    shm = shared_memory.SharedMemory(name=name)
    if not had_tracker:
        try:
            resource_tracker.unregister(shm._name, "shared_memory")
        except Exception:
            pass
    return shm


class ShmParameterServer:
    """Versioned parameter store in ONE posix shared-memory segment.

    The pytree structure is FIXED at construction from a template (the
    worker's initial params): leaves are serialised with the flat-key
    codec from ``checkpoint/io.py`` into preallocated aligned slots —
    a push is a plain ``memcpy`` per leaf, never a pickle.

    Concurrency is a single-writer seqlock (each server is written by
    exactly one role — model worker or policy worker):

    * ``push``: bump the sequence word to odd, copy payload, bump to
      even, then bump the version word (one atomic aligned 8-byte
      store). Version therefore never points at a torn payload.
    * ``pull_if_newer(version)``: ONE 8-byte read when unchanged — zero
      array copies, no lock to block on (``copies`` counts every leaf
      copied out; the unchanged path leaves it untouched). On a version
      change the payload is copied out inside a stable even-sequence
      window, retrying while a writer overlaps.
    * crash safety: a writer killed mid-push leaves the sequence odd;
      readers simply keep their cached value (degrade, not hang) and
      the restarted writer's next push re-synchronises the sequence.
      No cross-process lock exists, so there is nothing to repair.

    Benign race: version is bumped after the payload settles, so a
    reader can momentarily get a fresher payload with the previous
    version number — the next gated pull re-copies; never torn data.
    """

    _READ_RETRIES = 64

    def __init__(self, template):
        from multiprocessing import shared_memory

        from repro.checkpoint.io import LeafCodec
        self._codec = LeafCodec(template)
        self._offsets = []
        off = _SHM_HEADER
        for n in self._codec.nbytes:
            self._offsets.append(off)
            off += max(int(n), 1)
            off += (-off) % _SHM_ALIGN
        self._size = off
        shm = shared_memory.SharedMemory(create=True, size=self._size)
        self._name = shm.name
        self._shm = shm
        self._owner = True          # creator unlinks; children only close
        self._views = None
        shm.buf[:_SHM_HEADER] = b"\0" * _SHM_HEADER
        self.copies = 0             # client-local: leaves copied OUT
        self.pushes = 0             # client-local: pushes issued
        with _REGISTRY_LOCK:        # auditable lifetime (creator only)
            _SHM_REGISTRY[self._name] = self

    # -- pickling: children re-attach to the named segment lazily -------
    def __getstate__(self):
        state = dict(self.__dict__)
        state["_shm"] = None
        state["_views"] = None
        state["_owner"] = False
        return state

    def _seg(self):
        if self._shm is None:
            self._shm = _attach_shm(self._name)
        return self._shm

    def _leaf_views(self):
        if self._views is None:
            buf = self._seg().buf
            self._views = [
                np.frombuffer(buf, dtype=sd,
                              count=int(np.prod(sh, dtype=np.int64)),
                              offset=off).reshape(sh)
                for sd, sh, off in zip(self._codec.storable_dtypes,
                                       self._codec.shapes, self._offsets)]
        return self._views

    def _read_word(self, off) -> int:
        return struct.unpack_from("<q", self._seg().buf, off)[0]

    def _write_word(self, off, value) -> None:
        struct.pack_into("<q", self._seg().buf, off, value)

    def push(self, value) -> int:
        host = self._codec.encode(value)    # the one device->host hop
        views = self._leaf_views()
        seq = self._read_word(0)
        begin = seq + 1 + (seq % 2)         # next odd > seq, even if a
        self._write_word(0, begin)          # crashed writer left it odd
        for view, arr in zip(views, host):
            np.copyto(view, arr, casting="no")
        self._write_word(0, begin + 1)      # payload settled (even)
        ver = self._read_word(8) + 1        # single writer: RMW is safe
        self._write_word(8, ver)
        self.pushes += 1
        return ver

    def pull_if_newer(self, version: int, *, sharding=None):
        """(value, current_version) when newer than ``version`` else
        (None, version-as-seen). Unchanged cost: ONE aligned 8-byte read.
        ``sharding`` is accepted for interface parity with
        :class:`ParameterServer` and ignored: pulled leaves are host
        arrays — the worker re-homes them onto its own device/backend
        (each process owns a separate jax runtime)."""
        ver = self._read_word(8)
        if ver == version or ver == 0:
            return None, ver
        views = self._leaf_views()
        for _ in range(self._READ_RETRIES):
            s1 = self._read_word(0)
            if s1 % 2:                      # writer mid-copy
                time.sleep(0.0005)
                continue
            out = [np.array(v) for v in views]
            if self._read_word(0) == s1:    # no writer overlapped
                self.copies += len(out)
                # return the version read at ENTRY, not a re-read: the
                # payload is at least that fresh, and labelling it with
                # a version that completed during the copy would let
                # the next gated pull skip a push the caller never saw.
                # Worst case here is one redundant re-copy.
                return self._codec.decode(out), ver
        # writer crashed mid-push (sequence stuck odd) or pathological
        # contention: degrade — caller keeps its cache and retries later
        return None, version

    def pull(self):
        value, ver = self.pull_if_newer(-1)
        return value, (ver if value is not None else self.version)

    def pull_host(self):
        """Interface parity with ParameterServer: pulls are already
        host-materialised."""
        return self.pull()

    @property
    def version(self) -> int:
        return self._read_word(8)

    # -- lifecycle ------------------------------------------------------
    def close(self) -> None:
        """Drop this process's mapping (and unlink if creator).
        Idempotent; the creator's close also clears the audit registry
        entry, so ``live_shm_segments()`` proves reclamation."""
        self._views = None          # np views pin shm.buf; drop them first
        if self._shm is not None:
            self._shm.close()
            if self._owner:
                try:
                    self._shm.unlink()
                except FileNotFoundError:
                    pass
            self._shm = None
        if self._owner:
            with _REGISTRY_LOCK:
                _SHM_REGISTRY.pop(self._name, None)

    def __enter__(self) -> "ShmParameterServer":
        return self

    def __exit__(self, *exc) -> None:
        # teardown must not depend on GC order: runtime._run_procs holds
        # every server in one ExitStack so ALL exit paths reclaim
        self.close()


class BackpressureError(RuntimeError):
    """A ``ProcDataServer.push`` timed out on a full trajectory queue —
    the consumer (the model worker's drain -> ring-write path) is not
    keeping up with the collector fleet."""


class ProcDataServer:
    """Cross-process DataServer: a bounded trajectory queue. Collectors
    push host-materialised trajectories; the model worker drains them
    into its ring ReplayBuffer (Alg. 2 'move all trajectories from the
    remote buffer').

    Explicitly MULTI-PRODUCER (collector fleets, ISSUE 5): ``total_pushed``
    and the stopping-criterion tickets live behind ONE shared lock, so the
    global trajectory count stays exact under concurrent pushes from any
    number of collector processes AND across collector crash/restarts (a
    restarted collector resumes the global count instead of re-collecting
    from zero). ``try_claim(i, k)`` reserves up to ``k`` collection slots
    — ``min(k, remaining)``, batch-aware for env farms (ISSUE 6) — and
    adds them to collector ``i``'s in-flight COUNT; ``push`` /
    ``push_batch`` subtract what they deliver. A collector killed
    mid-batch leaves its undelivered tickets in flight — the supervising
    parent calls ``refund_inflight(i)`` when it respawns the worker and
    gets back exactly the stranded count, so a crash can never strand a
    ticket (stall) or push the COUNTER past the target (overshoot). A
    denied claim sleeps ``claim_backoff`` seconds before returning, so
    collectors that lose the race near the criterion back off instead of
    spin-polling. One documented residual window: a kill between the
    queue enqueue and the counter increment leaves refundable tickets
    whose trajectories already landed in the queue, so the replacement's
    pushes put EXTRA trajectories in the training stream —
    ``total_pushed`` (the stopping criterion) stays exact, the model
    just trains on a few extra trajectories. Closing it would need a
    transactional queue; the window is microseconds inside ``push``. A
    second residual window, inherited from the PR 4 counter: the ticket
    lock (and the mp.Queue's internal writer lock) is a plain
    non-robust mp lock, so a kill while one is held — a few counter
    updates, or a feeder-thread pipe write — leaves it held and stalls
    the other collectors. That failure is LOUD, not silent: stalled
    pushes hit ``push_timeout`` and raise :class:`BackpressureError`,
    the crashing collectors burn ``max_restarts`` and the parent fails
    the run. The shm parameter path stays deliberately lock-free (see
    ShmParameterServer).

    Backpressure: a push against a full queue waits ``push_timeout``
    seconds, then raises :class:`BackpressureError` naming the queue size
    and the slowest consumer instead of surfacing a bare ``queue.Full``.
    The timeout is a constructor argument threaded from
    ``RunConfig.push_timeout_s``."""

    def __init__(self, ctx, *, n_collectors: int = 1, maxsize: int = 512,
                 push_timeout: float = 30.0, target: Optional[int] = None,
                 claim_backoff: float = 0.002):
        self.n_collectors = max(int(n_collectors), 1)
        self.maxsize = int(maxsize)
        self.push_timeout = float(push_timeout)
        self.claim_backoff = float(claim_backoff)
        self._target = None if target is None else int(target)
        self._q = ctx.Queue(maxsize)
        # one lock guards ALL counters: total / tickets / in-flight
        # counts must move together for the criterion to be exact under
        # concurrent producers and supervisor refunds
        self._lock = ctx.Lock()
        self._total = ctx.Value("q", 0, lock=False)
        self._tickets = ctx.Value("q", 0, lock=False)
        self._inflight = ctx.Array("q", self.n_collectors, lock=False)
        self._closed = False
        self._creator = True        # children unpickle; only the creator
        with _REGISTRY_LOCK:        # process registers for the audit
            _DATA_REGISTRY[id(self)] = self

    def __getstate__(self):
        state = dict(self.__dict__)
        state["_creator"] = False   # a child's copy is not auditable here
        return state

    def _raise_backpressure(self, collector_id, timeout):
        raise BackpressureError(
            f"trajectory queue full: collector {collector_id} waited "
            f"{timeout:.1f}s to push and the queue still holds "
            f"{self.maxsize} (maxsize) undrained items. The slowest "
            "consumer is the model worker's drain->ring-write path "
            "(ModelLearningWorker._refresh_data); raise "
            "RunConfig.push_timeout_s, enlarge the queue, or check "
            "whether the model process is wedged/compiling."
        ) from None

    def push(self, traj, *, collector_id: int = 0,
             timeout: Optional[float] = None) -> int:
        host = jax.tree.map(np.asarray, traj)   # process boundary
        timeout = self.push_timeout if timeout is None else timeout
        try:
            self._q.put(host, timeout=timeout)
        except _queue.Full:
            self._raise_backpressure(collector_id, timeout)
        with self._lock:
            self._total.value += 1
            self._settle_inflight(collector_id, 1)
            return self._total.value

    def push_batch(self, batch, n: int, *, collector_id: int = 0,
                   timeout: Optional[float] = None) -> int:
        """Push ``n`` trajectories stacked as one batch (dict of
        (n, H, ...) arrays — a farm step's output). The whole batch is
        host-materialised once and rides the queue as ONE item (a farm
        at B=256 would otherwise blow through ``maxsize`` per step);
        ``drain`` unstacks it into per-trajectory dicts of zero-copy np
        views on the consumer side."""
        host = jax.tree.map(np.asarray, batch)  # process boundary
        timeout = self.push_timeout if timeout is None else timeout
        try:
            self._q.put(("batch", int(n), host), timeout=timeout)
        except _queue.Full:
            self._raise_backpressure(collector_id, timeout)
        with self._lock:
            self._total.value += int(n)
            self._settle_inflight(collector_id, int(n))
            return self._total.value

    def _settle_inflight(self, collector_id: int, n: int) -> None:
        # already holding self._lock. Claims are optional (pushes may
        # arrive unclaimed before a target is armed), so clamp at zero.
        i = collector_id % self.n_collectors
        self._inflight[i] = max(int(self._inflight[i]) - n, 0)

    def try_claim(self, collector_id: int = 0, k: int = 1) -> int:
        """Reserve up to ``k`` collection slots toward the global
        target; adds the grant to the collector's in-flight count until
        its pushes land. Returns ``min(k, remaining)`` — 0 once the
        target is fully claimed (no target configured: always ``k``).
        The denied path sleeps ``claim_backoff`` outside the lock so
        losers of the final-claim race back off instead of spinning."""
        k = int(k)
        with self._lock:
            g = k if self._target is None else \
                min(k, max(self._target - self._tickets.value, 0))
            if g > 0:
                self._tickets.value += g
                self._inflight[collector_id % self.n_collectors] += g
                return g
        time.sleep(self.claim_backoff)
        return 0

    def refund_inflight(self, collector_id: int) -> int:
        """Supervisor hook: return every ticket of a collector that died
        between claim and push (its in-flight count is still positive).
        Called by the parent when respawning collector ``collector_id``;
        returns the number of tickets refunded — a farm collector
        SIGKILLed mid-batch gets its WHOLE undelivered remainder back,
        so the criterion can still land exactly."""
        with self._lock:
            i = collector_id % self.n_collectors
            g = int(self._inflight[i])
            if g > 0:
                self._inflight[i] = 0
                self._tickets.value -= g
            return g

    def drain(self) -> List[Any]:
        """Move everything queued to the caller as a flat list of
        per-trajectory dicts; batch items are unstacked into zero-copy
        np views along their lane axis."""
        items: List[Any] = []
        while True:
            try:
                item = self._q.get_nowait()
            except _queue.Empty:
                return items
            if isinstance(item, tuple) and len(item) == 3 \
                    and item[0] == "batch":
                _, n, batch = item
                items.extend({k: v[i] for k, v in batch.items()}
                             for i in range(n))
            else:
                items.append(item)

    @property
    def total_pushed(self) -> int:
        with self._lock:
            return int(self._total.value)

    def __len__(self) -> int:
        try:
            return self._q.qsize()
        except NotImplementedError:     # macOS
            return 0

    def close(self) -> None:
        """Release this process's queue endpoint (feeder thread + pipe
        fds). Idempotent; the shared counters stay readable afterwards
        (``total_pushed`` still works for post-run reporting). The
        creator's close clears its audit-registry entry."""
        if self._closed:
            return
        self._closed = True
        self._q.close()
        self._q.join_thread()
        if self._creator:
            with _REGISTRY_LOCK:
                _DATA_REGISTRY.pop(id(self), None)

    def __enter__(self) -> "ProcDataServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# --------------------------------------------------------------------- ring
def _ring_write_impl(storage, traj, cursor):
    """Scatter one trajectory into the ring at ``cursor`` (wraps)."""
    h = jax.tree.leaves(traj)[0].shape[0]
    cap = jax.tree.leaves(storage)[0].shape[0]
    idx = (cursor + jnp.arange(h)) % cap
    return jax.tree.map(lambda buf, t: buf.at[idx].set(t), storage, traj)


_ring_write = jax.jit(_ring_write_impl, donate_argnums=(0,))


def _ring_write_burst_impl(storage, burst, n_rows, cursor):
    """Scatter a PADDED burst of stacked trajectories in ONE compiled
    write (collector fleets, ISSUE 5): ``burst`` leaves are
    ``(B, H, ...)`` stacks of which only the first ``n_rows`` flattened
    transitions (= M * H for M real trajectories) are valid. Padding
    rows are routed to index ``capacity`` — out of bounds — and DROPPED
    by the scatter (``mode="drop"``), so the shapes are static: one
    compile covers every burst size up to B, and a fleet's drain lands
    as one scatter instead of M sequential ring writes."""
    cap = jax.tree.leaves(storage)[0].shape[0]
    flat = jax.tree.map(
        lambda t: t.reshape((t.shape[0] * t.shape[1],) + t.shape[2:]),
        burst)
    rows = jax.tree.leaves(flat)[0].shape[0]
    r = jnp.arange(rows)
    idx = jnp.where(r < n_rows, (cursor + r) % cap, cap)
    return jax.tree.map(
        lambda buf, t: buf.at[idx].set(t, mode="drop"), storage, flat)


_ring_write_burst = jax.jit(_ring_write_burst_impl, donate_argnums=(0,))


class ReplayBuffer:
    """Preallocated fixed-capacity transition ring with a held-out
    validation ring (Alg. 2: the model learner trains on its LOCAL
    buffer; §4 'The local buffer is of fixed size and first-in-first-out').

    Replaces ``LocalBuffer``'s list-of-trajectories + per-epoch
    ``np.concatenate``: storage is device-resident, shapes are static, the
    write is a single compiled scatter, and FIFO eviction falls out of the
    ring cursor. ``train_view``/``val_view`` return the full-capacity
    arrays plus the count of valid rows — consumers sample/mask against
    that count, so their compiled shapes never change as data accumulates.

    ``sharding`` (role meshes, core/roles.py): a ``NamedSharding`` that
    shards the transition (leading) axis over the owning worker's
    sub-mesh. Storage is allocated PRE-SHARDED, incoming trajectories are
    replicated onto the sub-mesh before the scatter, and the ring write is
    compiled once with the storage's own ``out_shardings`` — so
    ``_ring_write`` and any trainer fed from ``train_view`` stay
    compile-once exactly as on a single device. Capacities are rounded up
    to the shard count (``jax.device_put`` rejects uneven shards).
    """

    def __init__(self, capacity: int, *, val_capacity: Optional[int] = None,
                 holdout_frac: float = 0.2, sharding=None,
                 burst_capacity: int = 8):
        self._sharding = sharding
        self.burst_capacity = max(int(burst_capacity), 1)
        if sharding is not None:
            from repro.core.roles import num_shards, replicated, round_up
            nsh = num_shards(sharding)
            capacity = round_up(capacity, nsh)
            val_capacity = round_up(
                max(int(capacity) // 4, 1) if val_capacity is None
                else val_capacity, nsh)
            self._traj_sharding = replicated(sharding.mesh)
            self._write = jax.jit(_ring_write_impl, donate_argnums=(0,),
                                  out_shardings=sharding)
            self._write_burst = jax.jit(_ring_write_burst_impl,
                                        donate_argnums=(0,),
                                        out_shardings=sharding)
        else:
            self._traj_sharding = None
            self._write = _ring_write
            self._write_burst = _ring_write_burst
        self.capacity = int(capacity)
        self.val_capacity = int(val_capacity if val_capacity is not None
                                else max(capacity // 4, 1))
        self.holdout_frac = holdout_frac
        self._every = (max(int(round(1 / holdout_frac)), 2)
                       if holdout_frac > 0 else 0)
        self._train: Optional[Dict[str, jax.Array]] = None
        self._val: Optional[Dict[str, jax.Array]] = None
        self._cursor = 0          # next train write position (transitions)
        self._written = 0         # total train transitions ever written
        self._val_cursor = 0
        self._val_written = 0
        self._trajs = 0           # total trajectories ever seen

    def _alloc(self, traj) -> None:
        def zeros(t, cap):
            t = jnp.asarray(t)
            z = jnp.zeros((cap,) + t.shape[1:], t.dtype)
            if self._sharding is not None:
                z = jax.device_put(z, self._sharding)
            return z
        self._train = {k: zeros(v, self.capacity) for k, v in traj.items()}
        if self._every:     # holdout_frac == 0 never writes the val ring
            self._val = {k: zeros(v, self.val_capacity)
                         for k, v in traj.items()}

    @staticmethod
    def _fit(traj, h: int, cap: int):
        """FIFO semantics for a trajectory longer than its ring: keep the
        last ``cap`` transitions (a duplicate-index scatter would
        otherwise write in undefined order)."""
        if h <= cap:
            return traj, h
        return {k: v[-cap:] for k, v in traj.items()}, cap

    def _write_one(self, traj, val: bool) -> None:
        """Single-trajectory compiled scatter into one ring (the M=1
        path; also the fallback for mixed horizons / traj > capacity)."""
        h = int(jax.tree.leaves(traj)[0].shape[0])
        if self._traj_sharding is not None:
            # cross-role ingestion: replicate the trajectory onto the
            # owning sub-mesh (explicit device->device, no host hop)
            traj = jax.device_put(traj, self._traj_sharding)
        if val:
            traj, h = self._fit(traj, h, self.val_capacity)
            self._val = self._write(self._val, traj,
                                    self._val_cursor % self.val_capacity)
            self._val_cursor = (self._val_cursor + h) % self.val_capacity
            self._val_written += h
        else:
            traj, h = self._fit(traj, h, self.capacity)
            self._train = self._write(self._train, traj,
                                      self._cursor % self.capacity)
            self._cursor = (self._cursor + h) % self.capacity
            self._written += h

    def _write_chunk(self, chunk, h: int, val: bool) -> None:
        """One compiled burst scatter for ``len(chunk)`` equal-horizon
        trajectories: stack to (M, H, ...), zero-pad to the fixed
        ``burst_capacity`` (padding rows are dropped by index), write."""
        b, m = self.burst_capacity, len(chunk)
        stacked = {k: jnp.stack([t[k] for t in chunk]) for k in chunk[0]}
        if m < b:
            stacked = {k: jnp.concatenate(
                [v, jnp.zeros((b - m,) + v.shape[1:], v.dtype)])
                for k, v in stacked.items()}
        if self._traj_sharding is not None:
            stacked = jax.device_put(stacked, self._traj_sharding)
        rows = m * h
        if val:
            self._val = self._write_burst(
                self._val, stacked, rows,
                self._val_cursor % self.val_capacity)
            self._val_cursor = (self._val_cursor + rows) % self.val_capacity
            self._val_written += rows
        else:
            self._train = self._write_burst(
                self._train, stacked, rows, self._cursor % self.capacity)
            self._cursor = (self._cursor + rows) % self.capacity
            self._written += rows

    def _burst_to_ring(self, group, val: bool) -> None:
        """Write a group of trajectories destined for ONE ring in as few
        compiled scatters as possible. Chunks are capped at
        ``burst_capacity`` trajectories AND at ``capacity`` valid rows:
        within a chunk every target index is distinct (scatter order
        irrelevant), and a later chunk overwrites an earlier one exactly
        like sequential FIFO writes — bit-identical ring contents."""
        cap = self.val_capacity if val else self.capacity
        i = 0
        while i < len(group):
            h0 = int(jax.tree.leaves(group[i])[0].shape[0])
            chunk, rows = [group[i]], h0
            i += 1
            while i < len(group) and len(chunk) < self.burst_capacity:
                h = int(jax.tree.leaves(group[i])[0].shape[0])
                if h != h0 or rows + h > cap:
                    break
                chunk.append(group[i])
                rows += h
                i += 1
            if len(chunk) == 1:
                self._write_one(chunk[0], val)
            else:
                self._write_chunk(chunk, h0, val)

    def add_traj(self, traj) -> None:
        """Insert one trajectory (dict of (H, ...) arrays). Every
        ``1/holdout_frac``-th trajectory goes to the validation ring."""
        if self._train is None:
            self._alloc(traj)
        self._trajs += 1
        traj = {k: jnp.asarray(v) for k, v in traj.items()}
        self._write_one(
            traj, val=bool(self._every and self._trajs % self._every == 0))

    def add_trajs(self, trajs) -> None:
        """Insert a BURST of trajectories (a fleet drain) with one
        compiled scatter per ring chunk instead of one write per
        trajectory. The deterministic train/val interleave advances
        per-trajectory in arrival order, exactly as repeated
        ``add_traj`` calls would."""
        trajs = list(trajs)
        if not trajs:
            return
        if self._train is None:
            self._alloc(trajs[0])
        groups = {False: [], True: []}
        for traj in trajs:
            self._trajs += 1
            traj = {k: jnp.asarray(v) for k, v in traj.items()}
            dest = bool(self._every and self._trajs % self._every == 0)
            groups[dest].append(traj)
        self._burst_to_ring(groups[False], val=False)
        self._burst_to_ring(groups[True], val=True)

    def extend(self, trajs) -> int:
        trajs = list(trajs)
        if len(trajs) == 1:
            self.add_traj(trajs[0])
        elif trajs:
            self.add_trajs(trajs)
        return len(trajs)

    def train_view(self) -> Tuple[Optional[Dict[str, jax.Array]], int]:
        """(full-capacity storage, number of valid rows). Static shapes,
        so a jitted trainer fed from here compiles exactly once.

        The view is a BORROW, not a snapshot: the next ``add_traj``
        donates these buffers back into the ring write (in-place on
        backends with buffer aliasing). Re-fetch after every insert and
        do not hold a view across writes."""
        return self._train, self.size

    def val_view(self) -> Tuple[Optional[Dict[str, jax.Array]], int]:
        return self._val, self.val_size

    @property
    def size(self) -> int:
        return min(self._written, self.capacity)

    @property
    def val_size(self) -> int:
        return min(self._val_written, self.val_capacity)

    @property
    def total_seen(self) -> int:
        """Total trajectories ever inserted (incl. evicted ones)."""
        return self._trajs


class LocalBuffer:
    """Legacy fixed-size FIFO list buffer with a held-out validation split.

    Superseded on the hot path by :class:`ReplayBuffer` (static shapes, no
    per-epoch concatenate); kept for tooling that wants host-side
    trajectory lists."""

    def __init__(self, max_trajs: int = 200, holdout_frac: float = 0.2):
        self.max_trajs = max_trajs
        self.holdout_frac = holdout_frac
        self._train: List[Any] = []
        self._val: List[Any] = []
        self._count = 0

    def extend(self, trajs) -> int:
        for t in trajs:
            self._count += 1
            # deterministic interleave keeps val non-empty and ~frac
            if self.holdout_frac > 0 and \
                    self._count % max(int(round(1 / self.holdout_frac)), 2) == 0:
                self._val.append(t)
                if len(self._val) > max(self.max_trajs // 4, 1):
                    self._val.pop(0)
            else:
                self._train.append(t)
                if len(self._train) > self.max_trajs:
                    self._train.pop(0)
        return len(trajs)

    def _stack(self, items):
        if not items:
            return None
        cat = {k: np.concatenate([np.asarray(t[k]) for t in items], axis=0)
               for k in items[0]}
        return cat

    def train_arrays(self):
        return self._stack(self._train)

    def val_arrays(self):
        return self._stack(self._val if self._val else self._train[-1:])

    @property
    def n_train(self):
        return len(self._train)

    @property
    def total_seen(self):
        return self._count
