"""The three servers of Figure 1a.

Workers communicate EXCLUSIVELY through these: a data buffer server and
two parameter servers (model, policy). Thread-safe, versioned; ``pull``
never blocks on a writer (the paper's lock-free spirit at phase
granularity — see DESIGN.md §2 for the TPU adaptation).

Hot-path invariants (see benchmarks/hotpath.py, which enforces them):

* ``ParameterServer`` keeps values DEVICE-RESIDENT. ``push``/``pull``
  never round-trip through the host; ``pull_host`` exists only for
  checkpoint / serving boundaries.
* ``ParameterServer.pull_if_newer(version)`` costs one lock + integer
  compare when the version is unchanged — no pytree traversal, no copy.
* ``ReplayBuffer`` is a preallocated fixed-capacity ring of static-shape
  arrays: no per-epoch ``np.concatenate``, no growing shapes, so a
  trainer compiled against ``train_view()`` never retraces.
"""
from __future__ import annotations

import threading
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# NOTE: on backends without buffer aliasing (CPU) the donated jits below
# warn once at compile that donation fell back to a copy — that is
# expected there and left visible on purpose (no global warning filter).


class ParameterServer:
    """Versioned pytree store (Alg. 1/2/3 'Pull/Push parameters').

    Values stay on device. ``push`` snapshots leaves with a device-side
    copy so published versions are isolated from training buffers that
    the pusher later donates back into its jitted update step.

    Placement-aware (role meshes, core/roles.py): ``push`` records the
    source sharding; ``pull_if_newer(version, sharding=...)`` moves the
    value onto the puller's sub-mesh with an explicit device-to-device
    ``device_put`` — only on a version change, and only when the source
    placement differs. The unchanged path stays one lock + int compare.
    """

    def __init__(self, initial=None):
        self._lock = threading.Lock()
        # snapshot like push(): the stored version must stay isolated
        # from buffers the caller may later donate into a jit
        self._value = None if initial is None else self._snapshot(initial)
        self._version = 0 if initial is None else 1
        self._src_sharding = (None if self._value is None
                              else self._leaf_sharding(self._value))

    @staticmethod
    def _snapshot(value):
        # device->device copy (cheap); NOT a host transfer. Isolates the
        # stored version from donate_argnums buffer reuse by the pusher.
        return jax.tree.map(jnp.copy, value)

    @staticmethod
    def _leaf_sharding(value):
        """Sharding of the pushed pytree (first jax leaf; one pytree holds
        one role's params, so leaves share a placement)."""
        for leaf in jax.tree.leaves(value):
            s = getattr(leaf, "sharding", None)
            if s is not None:
                return s
        return None

    def push(self, value) -> int:
        snap = self._snapshot(value)    # copy outside the lock
        src = self._leaf_sharding(snap)
        with self._lock:
            self._value = snap
            self._src_sharding = src
            self._version += 1
            return self._version

    def pull(self):
        """Returns (value, version); value is None until the first push."""
        with self._lock:
            return self._value, self._version

    def pull_if_newer(self, version: int, *, sharding=None):
        """Version-gated pull: returns (value, current_version) when the
        server holds something newer than ``version``, else
        (None, current_version). The unchanged path is one lock + int
        compare — no copies, no pytree traversal (and therefore no
        transfer of any kind: it passes jax.transfer_guard('disallow')).

        ``sharding``: the puller's target placement (e.g. params
        replicated over its role sub-mesh). Applied only on a version
        change, and skipped when the pusher already produced that
        placement — cross-role movement is a device-to-device
        ``device_put``, never a host round-trip."""
        with self._lock:
            if self._version == version or self._value is None:
                return None, self._version
            value, ver, src = self._value, self._version, self._src_sharding
        if sharding is not None and src != sharding:
            # outside the lock: value is an immutable snapshot; one
            # pytree-aware device_put batches all leaf transfers
            value = jax.device_put(value, sharding)
        return value, ver

    def pull_host(self):
        """Host-materialised pull for checkpoint / serving boundaries —
        the ONLY place a device->host copy of the store is allowed."""
        with self._lock:
            value, version = self._value, self._version
        if value is None:
            return None, version
        return jax.tree.map(np.asarray, value), version

    @property
    def version(self) -> int:
        with self._lock:
            return self._version


class DataServer:
    """FIFO trajectory buffer server (Alg. 1 'Push data', Alg. 2 line 3:
    'move all trajectories from the remote buffer').

    Zero-copy: pushed trajectories are stored by reference (jax arrays
    are immutable, so handing them across threads is safe) — no
    device->host materialisation on the hot path."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items: List[Any] = []
        self._total = 0

    def push(self, traj) -> int:
        with self._lock:
            self._items.append(traj)
            self._total += 1
            return self._total

    def drain(self) -> List[Any]:
        """Move ALL pending trajectories to the caller (empties server)."""
        with self._lock:
            items, self._items = self._items, []
            return items

    @property
    def total_pushed(self) -> int:
        with self._lock:
            return self._total

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)


# --------------------------------------------------------------------- ring
def _ring_write_impl(storage, traj, cursor):
    """Scatter one trajectory into the ring at ``cursor`` (wraps)."""
    h = jax.tree.leaves(traj)[0].shape[0]
    cap = jax.tree.leaves(storage)[0].shape[0]
    idx = (cursor + jnp.arange(h)) % cap
    return jax.tree.map(lambda buf, t: buf.at[idx].set(t), storage, traj)


_ring_write = jax.jit(_ring_write_impl, donate_argnums=(0,))


class ReplayBuffer:
    """Preallocated fixed-capacity transition ring with a held-out
    validation ring (Alg. 2: the model learner trains on its LOCAL
    buffer; §4 'The local buffer is of fixed size and first-in-first-out').

    Replaces ``LocalBuffer``'s list-of-trajectories + per-epoch
    ``np.concatenate``: storage is device-resident, shapes are static, the
    write is a single compiled scatter, and FIFO eviction falls out of the
    ring cursor. ``train_view``/``val_view`` return the full-capacity
    arrays plus the count of valid rows — consumers sample/mask against
    that count, so their compiled shapes never change as data accumulates.

    ``sharding`` (role meshes, core/roles.py): a ``NamedSharding`` that
    shards the transition (leading) axis over the owning worker's
    sub-mesh. Storage is allocated PRE-SHARDED, incoming trajectories are
    replicated onto the sub-mesh before the scatter, and the ring write is
    compiled once with the storage's own ``out_shardings`` — so
    ``_ring_write`` and any trainer fed from ``train_view`` stay
    compile-once exactly as on a single device. Capacities are rounded up
    to the shard count (``jax.device_put`` rejects uneven shards).
    """

    def __init__(self, capacity: int, *, val_capacity: Optional[int] = None,
                 holdout_frac: float = 0.2, sharding=None):
        self._sharding = sharding
        if sharding is not None:
            from repro.core.roles import num_shards, replicated, round_up
            nsh = num_shards(sharding)
            capacity = round_up(capacity, nsh)
            val_capacity = round_up(
                max(int(capacity) // 4, 1) if val_capacity is None
                else val_capacity, nsh)
            self._traj_sharding = replicated(sharding.mesh)
            self._write = jax.jit(_ring_write_impl, donate_argnums=(0,),
                                  out_shardings=sharding)
        else:
            self._traj_sharding = None
            self._write = _ring_write
        self.capacity = int(capacity)
        self.val_capacity = int(val_capacity if val_capacity is not None
                                else max(capacity // 4, 1))
        self.holdout_frac = holdout_frac
        self._every = (max(int(round(1 / holdout_frac)), 2)
                       if holdout_frac > 0 else 0)
        self._train: Optional[Dict[str, jax.Array]] = None
        self._val: Optional[Dict[str, jax.Array]] = None
        self._cursor = 0          # next train write position (transitions)
        self._written = 0         # total train transitions ever written
        self._val_cursor = 0
        self._val_written = 0
        self._trajs = 0           # total trajectories ever seen

    def _alloc(self, traj) -> None:
        def zeros(t, cap):
            t = jnp.asarray(t)
            z = jnp.zeros((cap,) + t.shape[1:], t.dtype)
            if self._sharding is not None:
                z = jax.device_put(z, self._sharding)
            return z
        self._train = {k: zeros(v, self.capacity) for k, v in traj.items()}
        if self._every:     # holdout_frac == 0 never writes the val ring
            self._val = {k: zeros(v, self.val_capacity)
                         for k, v in traj.items()}

    @staticmethod
    def _fit(traj, h: int, cap: int):
        """FIFO semantics for a trajectory longer than its ring: keep the
        last ``cap`` transitions (a duplicate-index scatter would
        otherwise write in undefined order)."""
        if h <= cap:
            return traj, h
        return {k: v[-cap:] for k, v in traj.items()}, cap

    def add_traj(self, traj) -> None:
        """Insert one trajectory (dict of (H, ...) arrays). Every
        ``1/holdout_frac``-th trajectory goes to the validation ring."""
        if self._train is None:
            self._alloc(traj)
        self._trajs += 1
        h = int(jax.tree.leaves(traj)[0].shape[0])
        traj = {k: jnp.asarray(v) for k, v in traj.items()}
        if self._traj_sharding is not None:
            # cross-role ingestion: replicate the trajectory onto the
            # owning sub-mesh (explicit device->device, no host hop)
            traj = jax.device_put(traj, self._traj_sharding)
        if self._every and self._trajs % self._every == 0:
            traj, h = self._fit(traj, h, self.val_capacity)
            self._val = self._write(self._val, traj,
                                    self._val_cursor % self.val_capacity)
            self._val_cursor = (self._val_cursor + h) % self.val_capacity
            self._val_written += h
        else:
            traj, h = self._fit(traj, h, self.capacity)
            self._train = self._write(self._train, traj,
                                      self._cursor % self.capacity)
            self._cursor = (self._cursor + h) % self.capacity
            self._written += h

    def extend(self, trajs) -> int:
        for t in trajs:
            self.add_traj(t)
        return len(trajs)

    def train_view(self) -> Tuple[Optional[Dict[str, jax.Array]], int]:
        """(full-capacity storage, number of valid rows). Static shapes,
        so a jitted trainer fed from here compiles exactly once.

        The view is a BORROW, not a snapshot: the next ``add_traj``
        donates these buffers back into the ring write (in-place on
        backends with buffer aliasing). Re-fetch after every insert and
        do not hold a view across writes."""
        return self._train, self.size

    def val_view(self) -> Tuple[Optional[Dict[str, jax.Array]], int]:
        return self._val, self.val_size

    @property
    def size(self) -> int:
        return min(self._written, self.capacity)

    @property
    def val_size(self) -> int:
        return min(self._val_written, self.val_capacity)

    @property
    def total_seen(self) -> int:
        """Total trajectories ever inserted (incl. evicted ones)."""
        return self._trajs


class LocalBuffer:
    """Legacy fixed-size FIFO list buffer with a held-out validation split.

    Superseded on the hot path by :class:`ReplayBuffer` (static shapes, no
    per-epoch concatenate); kept for tooling that wants host-side
    trajectory lists."""

    def __init__(self, max_trajs: int = 200, holdout_frac: float = 0.2):
        self.max_trajs = max_trajs
        self.holdout_frac = holdout_frac
        self._train: List[Any] = []
        self._val: List[Any] = []
        self._count = 0

    def extend(self, trajs) -> int:
        for t in trajs:
            self._count += 1
            # deterministic interleave keeps val non-empty and ~frac
            if self.holdout_frac > 0 and \
                    self._count % max(int(round(1 / self.holdout_frac)), 2) == 0:
                self._val.append(t)
                if len(self._val) > max(self.max_trajs // 4, 1):
                    self._val.pop(0)
            else:
                self._train.append(t)
                if len(self._train) > self.max_trajs:
                    self._train.pop(0)
        return len(trajs)

    def _stack(self, items):
        if not items:
            return None
        cat = {k: np.concatenate([np.asarray(t[k]) for t in items], axis=0)
               for k in items[0]}
        return cat

    def train_arrays(self):
        return self._stack(self._train)

    def val_arrays(self):
        return self._stack(self._val if self._val else self._train[-1:])

    @property
    def n_train(self):
        return len(self._train)

    @property
    def total_seen(self):
        return self._count
