"""Role partitioning of a pod mesh for async MBRL (DESIGN.md §2).

The paper runs three workers on three machines; on a TPU pod the analogue
is three device groups carved out of one mesh. ``split_roles`` slices the
leading (``data``/``pod``) axis into collector / model / policy sub-meshes
in a configurable ratio; each worker then jits its step functions against
its own sub-mesh while the host-side servers (core/servers.py) carry the
pulls/pushes between them.

Sharding conventions (enforced end-to-end by tests/_mesh_impl.py):

* parameters are REPLICATED over their role's sub-mesh (``replicated``);
* batch-like data (ring storage, imagined starts, TRPO batches) is
  sharded along the sub-mesh's leading axis (``batch_sharded``);
* cross-role movement happens only through ``ParameterServer.pull_if_newer
  (sharding=...)`` / ``ReplayBuffer`` ingestion — explicit device-to-device
  ``device_put``, never a host round-trip.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Tuple

import numpy as np
from jax.sharding import (Mesh, NamedSharding, PartitionSpec,
                          SingleDeviceSharding)


@dataclasses.dataclass(frozen=True)
class RoleSplit:
    collector: Mesh
    model: Mesh
    policy: Mesh
    shared: bool = False   # True: degenerate fallback, roles overlap
    axis: str | None = None    # the mesh axis the split was carved along;
    #                            also the batch axis workers shard over

    def describe(self) -> dict:
        return {
            "collector": list(self.collector.devices.shape),
            "model": list(self.model.devices.shape),
            "policy": list(self.policy.devices.shape),
            "shared": self.shared,
            "axis": self.axis,
        }


def replicated(mesh: Mesh) -> NamedSharding:
    """Params replicated over every device of a role sub-mesh."""
    return NamedSharding(mesh, PartitionSpec())


def batch_sharded(mesh: Mesh, axis: str | None = None) -> NamedSharding:
    """Leading (batch) dim sharded along one mesh axis, rest replicated."""
    axis = axis or mesh.axis_names[0]
    return NamedSharding(mesh, PartitionSpec(axis))


def collector_sharding(mesh: Mesh, collector_id: int = 0):
    """Placement of the ``collector_id``-th fleet member on the collector
    sub-mesh: collectors are sequential control loops (one robot each),
    so a fleet of N splits the sub-mesh one DEVICE per collector,
    round-robin when N exceeds the device count — instead of every
    collector pinning device 0 (the pre-fleet behaviour, which left the
    rest of the sub-mesh idle)."""
    return SingleDeviceSharding(
        mesh.devices.flat[collector_id % mesh.devices.size])


def num_shards(sharding: NamedSharding) -> int:
    """Number of shards along the leading dim of ``batch_sharded`` output
    (capacities/batches must be multiples of this: ``jax.device_put``
    rejects uneven shards)."""
    spec = sharding.spec
    if not spec or spec[0] is None:
        return 1
    axes = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
    return int(np.prod([sharding.mesh.shape[a] for a in axes]))


def round_up(n: int, multiple: int) -> int:
    return -(-int(n) // int(multiple)) * int(multiple)


def split_roles(mesh: Mesh, *, ratios: Tuple[int, int, int] = (1, 2, 1),
                axis: str | None = None) -> RoleSplit:
    """Carve the mesh's leading axis into three role sub-meshes.

    ratios: relative share of the split axis per (collector, model, policy).
    The split axis defaults to the FIRST axis with enough devices for all
    roles ("pod" on a mesh with >= 3 pods, otherwise "data" — a 2-pod
    (2,16,16) mesh splits its 16-wide data axis, not the 2-wide pod axis).

    Degenerate meshes (no axis with as many devices as roles, or an
    explicitly requested axis that is too small, or a ratio rounding that
    would starve a role) fall back to OVERLAPPING sub-meshes — every role
    gets the full mesh — with a warning, so small hosts run the same code
    path with trivial cross-role transfers."""
    names = list(mesh.axis_names)
    if axis is None:
        axis = next((a for a in names
                     if mesh.devices.shape[names.index(a)] >= len(ratios)),
                    names[0])
    ai = names.index(axis)
    n = int(mesh.devices.shape[ai])
    if n < len(ratios):
        warnings.warn(
            f"split_roles: axis {axis!r} has {n} device(s) for "
            f"{len(ratios)} roles; falling back to shared sub-meshes "
            "(all roles use the full mesh)", stacklevel=2)
        return RoleSplit(mesh, mesh, mesh, shared=True, axis=axis)
    total = sum(ratios)
    sizes = [max(1, n * r // total) for r in ratios]
    # fix rounding so sizes sum to n — never shrinking a role below 1
    while sum(sizes) > n:
        shrinkable = [i for i, s in enumerate(sizes) if s > 1]
        if not shrinkable:     # unreachable for n >= len(ratios); be safe
            warnings.warn("split_roles: ratio rounding starved a role; "
                          "falling back to shared sub-meshes", stacklevel=2)
            return RoleSplit(mesh, mesh, mesh, shared=True, axis=axis)
        i = max(shrinkable, key=sizes.__getitem__)
        sizes[i] -= 1
    while sum(sizes) < n:
        sizes[int(np.argmin(sizes))] += 1
    meshes = []
    start = 0
    for s in sizes:
        idx = [slice(None)] * mesh.devices.ndim
        idx[ai] = slice(start, start + s)
        sub = mesh.devices[tuple(idx)]
        meshes.append(Mesh(sub, mesh.axis_names))
        start += s
    return RoleSplit(*meshes, axis=axis)
