"""Role partitioning of a pod mesh for async MBRL (DESIGN.md §2).

The paper runs three workers on three machines; on a TPU pod the analogue
is three device groups carved out of one mesh. ``split_roles`` slices the
leading (``data``/``pod``) axis into collector / model / policy sub-meshes
in a configurable ratio; each worker then jits its step functions against
its own sub-mesh while the host-side servers (core/servers.py) carry the
pulls/pushes between them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class RoleSplit:
    collector: Mesh
    model: Mesh
    policy: Mesh


def split_roles(mesh: Mesh, *, ratios: Tuple[int, int, int] = (1, 2, 1),
                axis: str | None = None) -> RoleSplit:
    """Carve the mesh's leading axis into three role sub-meshes.

    ratios: relative share of the split axis per (collector, model, policy).
    The split axis defaults to the first axis ("pod" on multi-pod, "data"
    on a single pod)."""
    names = list(mesh.axis_names)
    axis = axis or names[0]
    ai = names.index(axis)
    n = mesh.devices.shape[ai]
    total = sum(ratios)
    sizes = [max(1, n * r // total) for r in ratios]
    # fix rounding so sizes sum to n
    while sum(sizes) > n:
        sizes[int(np.argmax(sizes))] -= 1
    while sum(sizes) < n:
        sizes[int(np.argmin(sizes))] += 1
    meshes = []
    start = 0
    for s in sizes:
        idx = [slice(None)] * mesh.devices.ndim
        idx[ai] = slice(start, start + s)
        sub = mesh.devices[tuple(idx)]
        meshes.append(Mesh(sub, mesh.axis_names))
        start += s
    return RoleSplit(*meshes)
