from repro.core.clock import RealClock, VirtualClock
from repro.core.runtime import (AsyncTrainer, PartialAsyncDataPolicy,
                                PartialAsyncModelPolicy, RunConfig,
                                SequentialTrainer)
from repro.core.servers import (DataServer, LocalBuffer, ParameterServer,
                                ReplayBuffer)
from repro.core.workers import (DataCollectionWorker, ModelLearningWorker,
                                PolicyImprovementWorker)
