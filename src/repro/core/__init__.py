from repro.core.clock import RealClock, VirtualClock
from repro.core.roles import RoleSplit, split_roles
from repro.core.runtime import (AsyncTrainer, PartialAsyncDataPolicy,
                                PartialAsyncModelPolicy, RunConfig,
                                SequentialTrainer, Supervisor,
                                SupervisorChain, clear_eval_cache)
from repro.core.servers import (BackpressureError, DataServer,
                                DataTransport, LocalBuffer,
                                ParameterServer, ParameterTransport,
                                ProcDataServer, ReplayBuffer,
                                ShmParameterServer, live_data_servers,
                                live_shm_segments, reclaim_ipc_resources)
from repro.core.workers import (DataCollectionWorker, ExplorationSchedule,
                                ModelLearningWorker,
                                PolicyImprovementWorker, ProcChannels,
                                ProcSpec, clear_rollout_cache,
                                heartbeat_slot, heartbeat_slots,
                                proc_worker_main)
