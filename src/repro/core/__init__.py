from repro.core.clock import RealClock, VirtualClock
from repro.core.roles import RoleSplit, split_roles
from repro.core.runtime import (AsyncTrainer, PartialAsyncDataPolicy,
                                PartialAsyncModelPolicy, RunConfig,
                                SequentialTrainer, clear_eval_cache)
from repro.core.servers import (BackpressureError, DataServer, LocalBuffer,
                                ParameterServer, ProcDataServer,
                                ReplayBuffer, ShmParameterServer)
from repro.core.workers import (DataCollectionWorker, ExplorationSchedule,
                                ModelLearningWorker,
                                PolicyImprovementWorker, ProcChannels,
                                ProcSpec, clear_rollout_cache,
                                proc_worker_main)
