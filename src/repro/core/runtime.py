"""Training engines.

* ``AsyncTrainer`` — the paper's contribution (Fig. 1a). Three execution
  modes sharing the same worker objects, each able to run a FLEET of
  ``n_collectors`` data-collection workers (the paper's Fig. 4
  parallel-collection story; Gu et al.'s multi-robot fan-out) against
  the one global ``total_trajs`` criterion — ticket-claimed, so N
  racing collectors finish with exactly ``total_trajs`` trajectories:
    - ``mode="event"``: deterministic discrete-event simulation. Each
      worker has a virtual-time cursor; the engine always advances the
      worker with the SMALLEST cursor, so relative speeds (robot control
      frequency vs. compute) are reproduced exactly — this is how the
      paper's Figures 2/3/5 are regenerated on CPU CI.
    - ``mode="threads"``: real host threads + RealClock (shares one GIL
      and one jax runtime: model/policy compute still steals cycles
      from the collector).
    - ``mode="procs"``: separate OS processes (spawn context, one jax
      backend each) talking through shared-memory parameter stores and
      a trajectory queue (servers.ShmParameterServer/ProcDataServer) —
      the paper's actual claim, "run time ~= data collection time", on
      a real multicore host. The parent supervises: periodic
      params+version snapshots via checkpoint/io.py, dead children
      restarted from the latest snapshot (a crash degrades the run
      instead of hanging it). See ROADMAP.md "Process-isolation
      invariants (PR 4)".
* ``SequentialTrainer`` — the classic synchronous baseline (Fig. 1b).
* ``PartialAsyncModelPolicy`` — §5.2 ablation (interleave model/policy).
* ``PartialAsyncDataPolicy`` — §5.3 ablation (interleave data/policy).

All engines record an eval trace: list of dicts
(time, trajs, env_steps, eval_return) — one row per evaluation.
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import tempfile
import threading
import time
from contextlib import ExitStack
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.roles import RoleSplit, split_roles
from repro.core.servers import (DataServer, ParameterServer, ProcDataServer,
                                ShmParameterServer)
from repro.core.workers import (DataCollectionWorker, ExplorationSchedule,
                                ModelLearningWorker,
                                PolicyImprovementWorker, ProcChannels,
                                ProcSpec, default_burst, heartbeat_slots,
                                proc_worker_main)
from repro.mbrl import dynamics as DYN
from repro.mbrl import policy as PI


@dataclasses.dataclass
class RunConfig:
    total_trajs: int = 40              # global stopping criterion (§4)
    eval_every_policy_steps: int = 5
    eval_rollouts: int = 4
    seed: int = 0
    # virtual durations for the event engine
    model_epoch_time: float = 1.0
    policy_step_time: float = 1.25   # ~GPU TRPO update on an imagined batch;
                                     # calibrated so async>=sync on all envs
                                     # (see benchmarks; Fig 5b still holds)
    collect_speed: float = 1.0         # Fig. 5b: 2.0 = twice as fast
    ema_weight: float = 0.9            # Fig. 5a
    early_stop: bool = True
    min_warmup_trajs: int = 4          # initial dataset before model pushes
    # collector fleet (ISSUE 5, the paper's Fig. 4 parallel-collection
    # story): N data-collection workers in every mode, sharing the ONE
    # global total_trajs criterion (ticket-claimed, so it lands exactly).
    # collect_noise optionally sets per-collector exploration noise
    # scales (cycled across the fleet); None = every collector at 1.0.
    n_collectors: int = 1
    collect_noise: Optional[tuple] = None
    # env farm (ISSUE 6): each collector simulates B envs per step via
    # one vmapped rollout (Env.rollout_batch) and pushes the whole batch
    # at once; tickets are claimed min(B, remaining) so the global
    # criterion still lands exactly. 1 = the pre-farm engine, bit for
    # bit (the single-rollout compiled program, one key split per step).
    envs_per_collector: int = 1
    # threads mode: sleep out each trajectory's robot time (horizon * dt /
    # collect_speed) so wall-clock reproduces the paper's real-robot rate
    # instead of racing simulated rollouts at compute speed
    pace_collection: bool = False
    # procs mode: how long a collector may block on a full trajectory
    # queue before ProcDataServer raises its descriptive
    # BackpressureError (servers.py)
    push_timeout_s: float = 30.0
    # procs mode: parent supervision — snapshot cadence for the
    # params+versions checkpoint (checkpoint/io.py), where to put it
    # (None -> fresh temp dir), and how many crash-restarts each worker
    # role gets before the run is declared failed
    snapshot_every_s: float = 2.0
    ckpt_dir: Optional[str] = None
    max_restarts: int = 3
    # procs mode: after the collector reaches total_trajs, keep the
    # learner processes running until their servers reach these versions
    # (0 = stop immediately, the paper's pure criterion). A simulated
    # collector can outrun the learners' first XLA compile entirely; CI
    # uses this to assert the run actually trained. The model worker
    # only pushes after min_warmup_trajs, so never set
    # min_final_model_version > 0 with total_trajs < min_warmup_trajs.
    min_final_model_version: int = 0
    min_final_policy_version: int = 0
    # transport behind the servers (PR 9): "shm" keeps the in-process /
    # posix-shm fast path (zero-copy unchanged pulls, single host);
    # "tcp" routes every server through the socket control plane
    # (src/repro/net) — same version-gating and exact-criterion ticket
    # contracts across a machine boundary, and remote collectors may
    # join a live run via `--connect`. threads/procs modes only (the
    # event engine is a single-process simulation).
    transport: str = "shm"
    # tcp: "host:port" the control plane listens on. None = loopback
    # with an ephemeral port (tests, single-host runs); "0.0.0.0:5555"
    # publishes the plane for remote joiners.
    bind: Optional[str] = None


# One compiled eval program per (env, n_rollouts): every _Recorder used
# to build (and trace) its own jitted lambda, so each trainer instance
# paid a fresh compile for the same env — benchmarks build dozens.
# The cache is strongly keyed on the env VALUE (envs are small frozen
# dataclasses, so value-equal instances share one compiled program) but
# BOUNDED: LRU eviction caps it at _EVAL_CACHE_MAX entries and
# ``clear_eval_cache()`` empties it between benchmark sweep groups, so
# sweeping many env variants can no longer grow it without bound, and
# an evicted entry strands nothing (each _Recorder holds its own fn,
# which stays valid standalone). Weakref keying was tried and rejected:
# a weak key must compare like its referent to share across value-equal
# envs, but then ANY death order of sharers either evicts an entry a
# live trainer still needs or strands dead-keyed entries that can never
# be hit again.
_EVAL_CACHE: Dict[Any, Callable] = {}
_EVAL_CACHE_MAX = 64


def clear_eval_cache() -> None:
    """Drop every cached eval program (and the env values keying them).
    Benchmarks call this between sweep groups."""
    _EVAL_CACHE.clear()


def _eval_fn(env, eval_rollouts: int):
    cache_key = (env, eval_rollouts)
    fn = _EVAL_CACHE.pop(cache_key, None)   # pop + reinsert = LRU touch
    if fn is None:
        fn = jax.jit(lambda p, k: jnp.mean(jax.vmap(
            lambda kk: env.rollout(
                kk, lambda pp, s, k2: PI.deterministic_action(pp, s),
                p)["rew"].sum())(jax.random.split(k, eval_rollouts))))
    _EVAL_CACHE[cache_key] = fn
    while len(_EVAL_CACHE) > _EVAL_CACHE_MAX:   # dicts iterate insertion-
        del _EVAL_CACHE[next(iter(_EVAL_CACHE))]    # order: oldest first
    return fn


class _Recorder:
    def __init__(self, env, eval_rollouts):
        self.env = env
        self.n = eval_rollouts
        self.trace: List[Dict[str, float]] = []
        self._eval = _eval_fn(env, eval_rollouts)

    def record(self, t, trajs, policy_params, key):
        ret = float(self._eval(policy_params, key))
        self.trace.append({"time": float(t), "trajs": int(trajs),
                           "env_steps": int(trajs * self.env.horizon),
                           "eval_return": ret})
        return ret


class Supervisor:
    """Hook seam into ``AsyncTrainer(mode="procs")`` supervision (PR 7).

    The parent's supervision loop calls these at well-defined points; the
    default implementation is a no-op, so plugging one in changes NOTHING
    about a healthy run. ``repro.chaos`` builds its fault-injection engine
    and always-on invariant monitor entirely on this seam — the trainer
    itself knows nothing about chaos.

    Lifecycle (all calls happen in the PARENT process):

    * ``attach(trainer)``      once, before any child is spawned.
    * ``on_spawn(role, proc, resume)``  after every child start
      (initial spawns and crash-restarts alike).
    * ``on_tick()``            every supervision-loop iteration (~50 Hz);
      the place to inject faults and check invariants DURING the run.
    * ``on_child_exit(role, exitcode, n_restarts)``  when the parent
      detects a dead child, BEFORE the budget check — fires even for the
      crash that exhausts the budget.
    * ``respawn_delay(role) -> float``  seconds to delay that role's
      crash-restart (0 = immediate, the pre-PR-7 behaviour). While
      delayed, the dead child stays visible in ``trainer._procs``.
    * ``on_snapshot(step)``    after every parent checkpoint attempt.
    * ``on_complete()``        when the stopping criterion is reached
      cleanly, before learner shutdown — last chance to un-stall
      children (SIGCONT) so the clean joins can proceed.
    * ``on_teardown(procs)``   FIRST thing in the teardown path, clean or
      not — must leave every child in a joinable state.
    """

    trainer: Any = None

    def attach(self, trainer) -> None:
        self.trainer = trainer

    def detach(self) -> None:
        """Drop the trainer reference. The trainer calls this LAST in
        its teardown: ``attach`` makes trainer<->supervisor a reference
        cycle, and breaking it lets refcounting free every mp primitive
        (locks, events, semaphore names in /dev/shm) the moment the
        caller releases the trainer — the ResourceAuditor's
        guaranteed-reclaim contract — instead of whenever the cycle
        collector next runs."""
        self.trainer = None

    def on_spawn(self, role: str, proc, resume: bool) -> None:
        pass

    def on_tick(self) -> None:
        pass

    def on_child_exit(self, role: str, exitcode: int,
                      n_restarts: int) -> None:
        pass

    def respawn_delay(self, role: str) -> float:
        return 0.0

    def on_snapshot(self, step: int) -> None:
        pass

    def on_complete(self) -> None:
        pass

    def on_teardown(self, procs: Dict[str, Any]) -> None:
        pass


class SupervisorChain(Supervisor):
    """Fan one supervision seam out to several supervisors (e.g. a chaos
    injector plus an invariant monitor). Hooks are called in order;
    ``respawn_delay`` is the MAX across members (the most patient member
    wins — a delayed respawn is the riskier schedule, which is what a
    chaos run wants to exercise)."""

    def __init__(self, *members: Supervisor):
        self.members = list(members)

    def attach(self, trainer) -> None:
        self.trainer = trainer
        for m in self.members:
            m.attach(trainer)

    def detach(self) -> None:
        self.trainer = None
        for m in self.members:
            m.detach()

    def on_spawn(self, role, proc, resume) -> None:
        for m in self.members:
            m.on_spawn(role, proc, resume)

    def on_tick(self) -> None:
        for m in self.members:
            m.on_tick()

    def on_child_exit(self, role, exitcode, n_restarts) -> None:
        for m in self.members:
            m.on_child_exit(role, exitcode, n_restarts)

    def respawn_delay(self, role) -> float:
        return max([m.respawn_delay(role) for m in self.members],
                   default=0.0)

    def on_snapshot(self, step) -> None:
        for m in self.members:
            m.on_snapshot(step)

    def on_complete(self) -> None:
        for m in self.members:
            m.on_complete()

    def on_teardown(self, procs) -> None:
        for m in self.members:
            m.on_teardown(procs)


class AsyncTrainer:
    def __init__(self, env, ens_cfg: DYN.EnsembleConfig, algo,
                 run_cfg: Optional[RunConfig] = None, *,
                 mode: str = "event", mesh=None,
                 roles: Optional[RoleSplit] = None,
                 role_ratios=(1, 2, 1), role_axis: Optional[str] = None,
                 algo_cfg=None, pol_cfg=None,
                 n_collectors: Optional[int] = None,
                 envs_per_collector: Optional[int] = None,
                 exploration: Optional[ExplorationSchedule] = None,
                 supervisor: Optional[Supervisor] = None):
        """``mesh``/``roles``: run each worker against its own role
        sub-mesh (core/roles.py). Pass a ``roles`` RoleSplit directly, or
        a ``mesh`` to split by ``role_ratios`` along ``role_axis``.
        Default (both None) is the single-device behaviour — all existing
        callers and the event engine are untouched.

        ``n_collectors``: size of the data-collection fleet (overrides
        ``run_cfg.n_collectors``). All three modes run N collectors
        against the one global ``total_trajs`` criterion; collector 0's
        RNG stream is identical to the lone collector's, so N=1 is
        bit-for-bit the pre-fleet engine. ``exploration`` plugs in a
        per-collector :class:`~repro.core.workers.ExplorationSchedule`
        (default: built from ``run_cfg.collect_noise``, or uniform 1.0).

        ``envs_per_collector``: the env farm (ISSUE 6) — each collector
        runs B simulated robots per step through one vmapped rollout
        (overrides ``run_cfg.envs_per_collector``; B=1 is the pre-farm
        engine bit for bit).

        ``mode="procs"`` additionally requires ``algo_cfg``/``pol_cfg``
        (plain-config AlgoConfig/PolicyConfig): spawned children cannot
        unpickle a built algo (it closes over jitted callables) — they
        rebuild it from configs. ``algo=None`` is then allowed and built
        here the same way (make_algo).

        ``supervisor``: a :class:`Supervisor` hooked into the procs-mode
        supervision loop (fault injection, invariant monitoring — see
        ``repro.chaos``). Procs-mode only."""
        if supervisor is not None and mode != "procs":
            raise ValueError(
                f'supervisor= hooks into the mode="procs" supervision '
                f"loop only (got mode={mode!r})")
        self.supervisor = supervisor
        if mode == "procs":
            if algo_cfg is None or pol_cfg is None:
                raise ValueError(
                    'mode="procs" needs algo_cfg= and pol_cfg= (children '
                    "rebuild the algorithm from plain configs)")
            if mesh is not None or roles is not None:
                raise ValueError(
                    'mode="procs" does not take a role mesh: each child '
                    "owns its whole local backend (per-process meshes "
                    "are future work, see ROADMAP.md)")
            if algo is None:
                from repro.mbrl.algos import make_algo
                algo = make_algo(algo_cfg, pol_cfg, jax.vmap(env.reward),
                                 env.reset_batch)
        self.algo_cfg = algo_cfg
        self.pol_cfg = pol_cfg
        self.ens_cfg = ens_cfg
        self.env = env
        # fresh per-instance config: a shared mutable default would leak
        # one caller's tweaks into every later trainer
        run_cfg = RunConfig() if run_cfg is None else run_cfg
        if n_collectors is not None:
            run_cfg = dataclasses.replace(run_cfg,
                                          n_collectors=int(n_collectors))
        if envs_per_collector is not None:
            run_cfg = dataclasses.replace(
                run_cfg, envs_per_collector=int(envs_per_collector))
        if run_cfg.n_collectors < 1:
            raise ValueError(f"n_collectors must be >= 1, got "
                             f"{run_cfg.n_collectors}")
        if run_cfg.envs_per_collector < 1:
            raise ValueError(f"envs_per_collector must be >= 1, got "
                             f"{run_cfg.envs_per_collector}")
        if run_cfg.transport not in ("shm", "tcp"):
            raise ValueError(f"transport must be 'shm' or 'tcp', got "
                             f"{run_cfg.transport!r}")
        if run_cfg.transport == "tcp" and mode == "event":
            raise ValueError(
                'transport="tcp" needs a real engine (mode="threads" or '
                '"procs"): the event engine is a single-process virtual-'
                "clock simulation with nothing to transport")
        self.run_cfg = run_cfg
        self.exploration = exploration if exploration is not None else (
            ExplorationSchedule(tuple(run_cfg.collect_noise))
            if run_cfg.collect_noise else ExplorationSchedule())
        self.mode = mode
        if roles is None and mesh is not None:
            roles = split_roles(mesh, ratios=tuple(role_ratios),
                                axis=role_axis)
        self.roles = roles
        key = jax.random.key(run_cfg.seed)
        kc, km, kp, self._keval = jax.random.split(key, 4)
        # transport seam (PR 9): threads + tcp runs every server through
        # ONE socket control plane — the workers are transport-blind
        # (identical method surface), only the handles change. Codecs
        # are fixed lazily from the first push (the workers that own the
        # templates are constructed just below). procs mode selects its
        # transport inside _run_procs; shm (default) is this block's
        # else-branch, bit for bit the previous engine.
        self._plane = None
        if run_cfg.transport == "tcp" and mode == "threads":
            from repro.net import ControlPlane
            self._plane = ControlPlane(run_cfg.bind or "127.0.0.1:0")
            self.model_server = self._plane.parameter_server("model")
            self.policy_server = self._plane.parameter_server("policy")
            self.data_server = self._plane.data_server(
                n_collectors=run_cfg.n_collectors,
                push_timeout=run_cfg.push_timeout_s)
        else:
            self.data_server = DataServer()
            self.model_server = ParameterServer()
            self.policy_server = ParameterServer()
        # workers shard batches along the axis the split was carved on
        # (NOT axis_names[0]: on a 2-pod mesh the split skips the 2-wide
        # 'pod' axis and carves 'data')
        self.policy_worker = PolicyImprovementWorker(
            algo, self.policy_server, self.model_server, kp,
            mesh=roles.policy if roles else None,
            batch_axis=roles.axis if roles else None)
        # the collector FLEET: every member shares the policy/data
        # servers but owns its RNG stream (collector 0 = the lone
        # collector's stream), its exploration rung, and — under a role
        # mesh — its own device of the collector sub-mesh (round-robin).
        # procs mode: the real fleet is rebuilt inside child processes
        # from ProcSpec, so the parent keeps ONE mirror collector (the
        # back-compat `collector` alias) instead of N idle jit wrappers.
        n_local = 1 if mode == "procs" else run_cfg.n_collectors
        self.collectors = [
            DataCollectionWorker(
                env, self.policy_server, self.data_server,
                self.policy_worker.state["policy"], kc,
                speed=run_cfg.collect_speed,
                mesh=roles.collector if roles else None,
                collector_id=i,
                noise_scale=self.exploration.scale_for(i),
                envs_per_step=run_cfg.envs_per_collector)
            for i in range(n_local)]
        self.collector = self.collectors[0]     # back-compat alias
        self.model_worker = ModelLearningWorker(
            ens_cfg, self.data_server, self.model_server, km,
            ema_weight=run_cfg.ema_weight, early_stop=run_cfg.early_stop,
            min_trajs=run_cfg.min_warmup_trajs,
            mesh=roles.model if roles else None,
            batch_axis=roles.axis if roles else None,
            burst=default_burst(run_cfg.n_collectors,
                                run_cfg.envs_per_collector))
        self.recorder = _Recorder(env, run_cfg.eval_rollouts)

    # ------------------------------------------------------------- event
    def run(self) -> List[Dict[str, float]]:
        try:
            if self.mode == "threads":
                return self._run_threads()
            if self.mode == "procs":
                return self._run_procs()
            return self._run_event()
        finally:
            # threads + tcp: the trainer owns the control plane for ONE
            # run. Snapshot the final versions/count (post-run asserts
            # read them), then shut the plane and its client handles —
            # this trainer is single-run, like every engine here.
            if self._plane is not None:
                try:
                    self.net_info = {
                        "model_version": int(self.model_server.version),
                        "policy_version": int(self.policy_server.version),
                        "trajs": int(self.data_server.total_pushed)}
                except Exception:
                    pass
                for srv in (self.model_server, self.policy_server,
                            self.data_server):
                    srv.close()
                self._plane.close()
                self._plane = None

    def _run_event(self):
        rc = self.run_cfg
        traj_t = (self.env.horizon * self.env.dt) / rc.collect_speed
        # cursors: virtual time at which each worker becomes free. The
        # FLEET gets one cursor per collector, so N collectors overlap
        # in virtual time exactly like N robots (Fig. 4) — and the
        # interleaving is deterministic per seed: ties resolve by dict
        # insertion order, every collector owns its RNG stream, so the
        # schedule (and the trace) is a pure function of the RunConfig.
        cur = {f"collect:{i}": 0.0 for i in range(len(self.collectors))}
        cur.update({"model": 0.0, "policy": 0.0})
        collect_t = (lambda: max(cur[f"collect:{i}"]
                                 for i in range(len(self.collectors))))
        ds = self.data_server
        since_eval = 0
        B = rc.envs_per_collector
        while ds.total_pushed < rc.total_trajs:
            w = min(cur, key=cur.get)
            t = cur[w]
            if w.startswith("collect:"):
                # env farm: B robots run in PARALLEL, so a batch step
                # still advances this collector's cursor by ONE
                # trajectory time. The single-threaded engine needs no
                # tickets — claim min(B, remaining) directly so the
                # criterion lands exactly when B doesn't divide it.
                g = min(B, rc.total_trajs - ds.total_pushed)
                self.collectors[int(w.split(":", 1)[1])].step(g)
                cur[w] = t + traj_t
            elif w == "model":
                out = self.model_worker.step()
                # idle model worker re-checks for data shortly
                cur[w] = t + (rc.model_epoch_time if out is not None
                              else min(traj_t, rc.model_epoch_time) * 0.5)
            else:
                did = self.policy_worker.step()
                cur[w] = t + (rc.policy_step_time if did
                              else min(traj_t, rc.policy_step_time) * 0.5)
                if did:
                    since_eval += 1
                    if since_eval >= rc.eval_every_policy_steps:
                        since_eval = 0
                        self._keval, k = jax.random.split(self._keval)
                        self.recorder.record(
                            collect_t(), ds.total_pushed,
                            self.policy_worker.state["policy"], k)
        # final eval at the end of collection
        self._keval, k = jax.random.split(self._keval)
        self.recorder.record(collect_t(), ds.total_pushed,
                             self.policy_worker.state["policy"], k)
        return self.recorder.trace

    # ----------------------------------------------------------- threads
    def _run_threads(self):
        rc = self.run_cfg
        stop = threading.Event()
        t0 = time.monotonic()   # all trace rows are relative to t0
        ds = self.data_server
        # fleet stopping criterion: each collector CLAIMS a slot before
        # collecting (one lock in the server), so the run finishes with
        # total_pushed EXACTLY total_trajs — N racing collectors can
        # never overshoot the paper's global criterion
        ds.set_target(rc.total_trajs)

        collect_errors: List[tuple] = []

        def collect_loop(w):
            while not stop.is_set():
                # env farm: claim up to a whole batch of slots; the
                # server grants min(B, remaining), so the last batch
                # shrinks to land the criterion exactly
                g = ds.try_claim(w.collector_id, k=w.envs_per_step)
                if not g:
                    break
                t_step = time.monotonic()
                try:
                    dur = w.step(g)
                except Exception as e:
                    # a dead thread cannot refund its claimed tickets, so
                    # the run would otherwise 'complete' trajectories
                    # short with only a stderr traceback — record it and
                    # re-raise from the MAIN thread after the joins
                    collect_errors.append((w.collector_id, e))
                    stop.set()
                    return
                if rc.pace_collection and dur is not None:
                    # emulate the robot's control frequency: a trajectory
                    # occupies `dur` seconds of real time regardless of
                    # how fast the simulated rollout computes
                    time.sleep(max(dur - (time.monotonic() - t_step), 0.0))

        def model_loop():
            while not stop.is_set():
                if self.model_worker.step() is None:
                    time.sleep(0.002)

        def policy_loop():
            n = 0
            while not stop.is_set():
                if self.policy_worker.step():
                    n += 1
                    if n % rc.eval_every_policy_steps == 0:
                        self._keval, k = jax.random.split(self._keval)
                        self.recorder.record(
                            time.monotonic() - t0, ds.total_pushed,
                            self.policy_worker.state["policy"], k)
                else:
                    time.sleep(0.002)

        collect_threads = [
            threading.Thread(target=collect_loop, args=(w,), daemon=True,
                             name=f"collect:{w.collector_id}")
            for w in self.collectors]
        learner_threads = [threading.Thread(target=f, daemon=True)
                           for f in (model_loop, policy_loop)]
        for th in collect_threads + learner_threads:
            th.start()
        for th in collect_threads:  # every claimed slot has been pushed
            th.join()               # once the whole fleet exits
        stop.set()
        for th in learner_threads:
            th.join(timeout=10)
        if collect_errors:
            cid, err = collect_errors[0]
            raise RuntimeError(
                f"collector {cid} failed mid-run; the fleet stopped at "
                f"{ds.total_pushed}/{rc.total_trajs} trajectories"
            ) from err
        self._keval, k = jax.random.split(self._keval)
        self.recorder.record(time.monotonic() - t0, ds.total_pushed,
                             self.policy_worker.state["policy"], k)
        return self.recorder.trace

    # ------------------------------------------------------------- procs
    def _drain_trace(self, trace_q) -> None:
        while True:
            try:
                self.recorder.trace.append(trace_q.get_nowait())
            except _queue.Empty:
                return

    def _snapshot(self, ckpt_dir, model_srv, policy_srv, step) -> int:
        """Checkpoint params+versions of both stores. Until a store's
        first push, its slot holds the (deterministic) init params at
        version 0 — restoring that is exactly 'restart from scratch'.

        A DEGRADED pull (None despite version > 0: the writer died
        mid-push, or pathological contention) must NOT be snapshotted —
        substituting init params there would ratchet the newest
        checkpoint back to scratch and a restarting worker would
        republish it over trained progress. Keep the previous snapshot
        instead and let the next cycle retry."""
        from repro.checkpoint import io as ckpt_io
        m, mv = model_srv.pull_host()
        p, pv = policy_srv.pull_host()
        if (m is None and model_srv.version > 0) or \
                (p is None and policy_srv.version > 0):
            return step
        if m is None:
            m, mv = jax.tree.map(np.asarray, self.model_worker.params), 0
        if p is None:
            p, pv = jax.tree.map(
                np.asarray, self.policy_worker.state["policy"]), 0
        tree = {"model": m, "model_version": np.int64(mv),
                "policy": p, "policy_version": np.int64(pv)}
        ckpt_io.save_pytree(ckpt_dir, tree, step=step, keep=3)
        return step + 1

    def _run_procs(self):
        import multiprocessing as mp
        rc = self.run_cfg
        sup = self.supervisor if self.supervisor is not None else Supervisor()
        ctx = mp.get_context("spawn")   # NEVER fork: the parent's jax
        #                                 runtime must not leak into
        #                                 children (fork corrupts XLA)
        ckpt_dir = Path(rc.ckpt_dir) if rc.ckpt_dir else \
            Path(tempfile.mkdtemp(prefix="repro_procs_ckpt_"))
        # every IPC resource is owned by this ExitStack: whatever path
        # leaves this method — clean completion, budget RuntimeError, a
        # KeyboardInterrupt mid-spawn — closes all three servers, so no
        # teardown relies on GC order (chaos invariant: the
        # ResourceAuditor sweeps /dev/shm + fds afterwards and must find
        # zero leaks even after a chaotic run)
        with ExitStack() as stack:
            # transport seam (PR 9): the supervision loop below is
            # TRANSPORT-BLIND — both families expose the same methods
            # (pull_host/version for snapshots and completion,
            # refund_inflight for crash refunds), so everything after
            # this block is identical for shm and tcp.
            plane = None
            if rc.transport == "tcp":
                from repro.net import ControlPlane
                plane = stack.enter_context(
                    ControlPlane(rc.bind or "127.0.0.1:0"))
                model_srv = stack.enter_context(
                    plane.parameter_server("model",
                                           self.model_worker.params))
                policy_srv = stack.enter_context(
                    plane.parameter_server(
                        "policy", self.policy_worker.state["policy"]))
                # same ticket arming as the mp queue below; counters
                # live on the plane, so remote joiners (--connect)
                # share the one exact criterion
                data_srv = stack.enter_context(plane.data_server(
                    n_collectors=rc.n_collectors, target=rc.total_trajs,
                    push_timeout=rc.push_timeout_s))
            else:
                model_srv = stack.enter_context(
                    ShmParameterServer(self.model_worker.params))
                policy_srv = stack.enter_context(
                    ShmParameterServer(self.policy_worker.state["policy"]))
                # ticket-armed: N collector processes claim collection
                # slots from the shared server, so the global criterion
                # lands exactly even across collector crashes (the
                # parent refunds in-flight tickets)
                data_srv = stack.enter_context(
                    ProcDataServer(ctx, n_collectors=rc.n_collectors,
                                   target=rc.total_trajs,
                                   push_timeout=rc.push_timeout_s))
            trace_q = ctx.Queue()
            # the trace queue's pipe fds are parent-held IPC too: close
            # them with the servers, not at GC time
            stack.callback(trace_q.close)
            stop = ctx.Event()
            # lock-free liveness/compile telemetry: one (beat_time,
            # compile_count) double pair per role slot, written by
            # children, read by the parent's invariant monitor
            hb = ctx.Array("d", 2 * heartbeat_slots(rc.n_collectors),
                           lock=False)
            ch = ProcChannels(model_srv, policy_srv, data_srv, trace_q,
                              stop, t0=time.monotonic(), heartbeat=hb)
            spec = ProcSpec(self.env, self.ens_cfg, self.algo_cfg,
                            self.pol_cfg, rc, rc.seed,
                            exploration=self.exploration)
            if plane is not None:
                # publish the spec for remote joiners (--connect): a
                # joining host rebuilds a collector from it and claims
                # from the same ticket counters as the local fleet
                import pickle as _pickle
                plane.set_join_spec(_pickle.dumps(spec))
            # exposed for tests/benchmarks/chaos: kill-and-restart pokes
            # _procs, the hotpath bench reads server versions while the
            # run is live, supervisors read channels + restart counters
            self._proc_servers = {"model": model_srv, "policy": policy_srv,
                                  "data": data_srv}
            self._proc_channels = ch
            # the fleet: one supervised child per collector, each with
            # its OWN restart budget ("collector:3" crashing repeatedly
            # must not eat the other collectors' allowance)
            collector_roles = [f"collector:{i}"
                               for i in range(rc.n_collectors)]
            restarts = {r: 0 for r in ["model", "policy"] + collector_roles}
            # restarts is shared LIVE (not copied) so a supervisor's
            # on_tick sees budget consumption as it happens
            self.proc_info: Dict[str, Any] = {
                "restarts": restarts, "ckpt_dir": str(ckpt_dir)}

            def spawn(role, resume=False):
                # children must re-import repro whatever launched the
                # parent (pytest, a notebook, an installed script)
                import os

                import repro

                # namespace package: __file__ is None, __path__ has dir
                pkg_dir = (repro.__file__ and Path(repro.__file__).parent) \
                    or Path(next(iter(repro.__path__)))
                src_root = str(Path(pkg_dir).resolve().parent)
                old_pp = os.environ.get("PYTHONPATH")
                if src_root not in (old_pp or "").split(os.pathsep):
                    os.environ["PYTHONPATH"] = \
                        src_root + (os.pathsep + old_pp if old_pp else "")
                try:
                    p = ctx.Process(
                        target=proc_worker_main, name=f"repro-{role}",
                        args=(role, spec, ch,
                              str(ckpt_dir) if resume else None),
                        daemon=True)
                    p.start()
                finally:
                    if old_pp is None:
                        os.environ.pop("PYTHONPATH", None)
                    else:
                        os.environ["PYTHONPATH"] = old_pp
                sup.on_spawn(role, p, resume)
                return p

            self._procs = {}
            # roles whose crash-restart a supervisor delayed: role ->
            # monotonic deadline. The dead child stays in _procs (its
            # nonzero exitcode keeps the completion check honest) until
            # the deadline passes and the respawn actually happens.
            pending_respawn: Dict[str, float] = {}
            last_snap = time.monotonic()
            snap_step = 0
            sup.attach(self)
            try:
                for r in ["policy", "model"] + collector_roles:
                    self._procs[r] = spawn(r)
                while True:
                    self._drain_trace(trace_q)
                    sup.on_tick()
                    if all(self._procs[r].exitcode == 0
                           for r in collector_roles) and \
                            model_srv.version >= \
                            rc.min_final_model_version and \
                            policy_srv.version >= \
                            rc.min_final_policy_version:
                        break       # stopping criterion reached cleanly
                    for role, p in list(self._procs.items()):
                        ec = p.exitcode
                        if ec is None or ec == 0:
                            continue
                        if role in pending_respawn:
                            # crash already accounted; respawn when due
                            if time.monotonic() < pending_respawn[role]:
                                continue
                            del pending_respawn[role]
                            self._procs[role] = spawn(role, resume=True)
                            continue
                        restarts[role] += 1
                        sup.on_child_exit(role, ec, restarts[role])
                        if restarts[role] > rc.max_restarts:
                            raise RuntimeError(
                                f"{role} worker crashed (exit {ec}) more "
                                f"than max_restarts={rc.max_restarts} "
                                "times")
                        p.join()
                        if role.startswith("collector:"):
                            # a crash between claim and push would strand
                            # a ticket and stall the criterion: refund it
                            data_srv.refund_inflight(
                                int(role.split(":", 1)[1]))
                        # restart from the LATEST snapshot: the child
                        # reloads params+versions via checkpoint/io.py —
                        # immediately, unless a supervisor asks for a
                        # delayed respawn (chaos: the run must survive a
                        # role being DOWN for a while, not just bouncing)
                        delay = float(sup.respawn_delay(role))
                        if delay > 0:
                            pending_respawn[role] = \
                                time.monotonic() + delay
                        else:
                            self._procs[role] = spawn(role, resume=True)
                    if time.monotonic() - last_snap >= rc.snapshot_every_s:
                        snap_step = self._snapshot(ckpt_dir, model_srv,
                                                   policy_srv, snap_step)
                        sup.on_snapshot(snap_step)
                        last_snap = time.monotonic()
                    time.sleep(0.02)
                sup.on_complete()   # un-stall anything before clean joins
                stop.set()
                for role in ("model", "policy"):
                    self._procs[role].join(timeout=120)
                # final eval row arrives AFTER the policy child saw stop
                try:
                    self.recorder.trace.append(trace_q.get(timeout=10))
                except _queue.Empty:
                    pass
                self._drain_trace(trace_q)
                # adopt the children's final published params so the
                # parent looks exactly like a threads-mode trainer after
                m_final, mv = model_srv.pull_host()
                p_final, pv = policy_srv.pull_host()
                if p_final is not None:
                    self.policy_worker.state = {
                        **self.policy_worker.state,
                        "policy": jax.tree.map(jnp.asarray, p_final)}
                    self.policy_server.push(
                        self.policy_worker.state["policy"])
                if m_final is not None:
                    self.model_worker.params = jax.tree.map(
                        jnp.asarray, m_final)
                    self.model_server.push(self.model_worker.params)
                self.collector.collected = data_srv.total_pushed
                snap_step = self._snapshot(ckpt_dir, model_srv, policy_srv,
                                           snap_step)
                self.proc_info.update({
                    "model_version": int(mv), "policy_version": int(pv),
                    "restarts": dict(restarts),
                    "trajs": data_srv.total_pushed,
                    "n_collectors": rc.n_collectors,
                    "noise_scales": [self.exploration.scale_for(i)
                                     for i in range(rc.n_collectors)]})
            finally:
                # FIRST: let the supervisor make children joinable again
                # (a chaos stall leaves a child SIGSTOPped — terminate()
                # sends SIGTERM, which a stopped process never handles)
                try:
                    sup.on_teardown(self._procs)
                except Exception:
                    pass
                stop.set()
                for p in self._procs.values():
                    if p.is_alive():
                        p.join(timeout=10)
                    if p.is_alive():
                        p.terminate()
                        p.join(timeout=5)
                    if p.is_alive():
                        p.kill()    # SIGKILL: even a wedged/stopped
                        p.join(timeout=5)   # child must not outlive us
                # break the trainer<->supervisor cycle so refcounting
                # frees every remaining mp primitive (heartbeat arena,
                # locks, semaphore names) as soon as the caller drops
                # the trainer — see Supervisor.detach
                sup.detach()
                # servers close via the ExitStack on every exit path
        return self.recorder.trace


class SequentialTrainer:
    """Classic synchronous MBRL (Fig. 1b): collect N -> fit model to
    convergence (early stop / max epochs) -> G policy steps -> repeat."""

    def __init__(self, env, ens_cfg, algo,
                 run_cfg: Optional[RunConfig] = None,
                 *, n_rollouts: int = 5, max_model_epochs: int = 50,
                 policy_steps: int = 20):
        self.env = env
        run_cfg = RunConfig() if run_cfg is None else run_cfg
        self.run_cfg = run_cfg
        self.n_rollouts = n_rollouts
        self.max_model_epochs = max_model_epochs
        self.policy_steps = policy_steps
        key = jax.random.key(run_cfg.seed)
        kc, km, kp, self._keval = jax.random.split(key, 4)
        self.data_server = DataServer()
        self.model_server = ParameterServer()
        self.policy_server = ParameterServer()
        self.policy_worker = PolicyImprovementWorker(
            algo, self.policy_server, self.model_server, kp)
        self.collector = DataCollectionWorker(
            env, self.policy_server, self.data_server,
            self.policy_worker.state["policy"], kc)
        self.model_worker = ModelLearningWorker(
            ens_cfg, self.data_server, self.model_server, km,
            ema_weight=run_cfg.ema_weight, early_stop=run_cfg.early_stop,
            min_trajs=run_cfg.min_warmup_trajs)
        self.recorder = _Recorder(env, run_cfg.eval_rollouts)

    def run(self):
        rc = self.run_cfg
        t = 0.0
        traj_t = self.env.horizon * self.env.dt
        while self.collector.collected < rc.total_trajs:
            for _ in range(self.n_rollouts):
                self.collector.step()
                t += traj_t
            self.model_worker.stopper.reset()
            for _ in range(self.max_model_epochs):
                out = self.model_worker.step()
                if out is None:
                    break
                t += rc.model_epoch_time
            for i in range(self.policy_steps):
                if self.policy_worker.step():
                    t += rc.policy_step_time
            self._keval, k = jax.random.split(self._keval)
            self.recorder.record(t, self.collector.collected,
                                 self.policy_worker.state["policy"], k)
        return self.recorder.trace


class PartialAsyncModelPolicy(SequentialTrainer):
    """§5.2: collect N rollouts, then ALTERNATE (1 model epoch, G' policy
    steps) — policy sees models before they converge."""

    def run(self):
        rc = self.run_cfg
        t = 0.0
        traj_t = self.env.horizon * self.env.dt
        g_alt = max(self.policy_steps // self.max_model_epochs, 1)
        while self.collector.collected < rc.total_trajs:
            for _ in range(self.n_rollouts):
                self.collector.step()
                t += traj_t
            self.model_worker.stopper.reset()
            for e in range(self.max_model_epochs):
                out = self.model_worker.step()
                if out is not None:
                    t += rc.model_epoch_time
                for _ in range(g_alt):
                    if self.policy_worker.step():
                        t += rc.policy_step_time
                if out is None:
                    break
            self._keval, k = jax.random.split(self._keval)
            self.recorder.record(t, self.collector.collected,
                                 self.policy_worker.state["policy"], k)
        return self.recorder.trace


class PartialAsyncDataPolicy(SequentialTrainer):
    """§5.3: fit the model, then ALTERNATE (G policy steps, collect one
    rollout) N times — collection uses fresh mid-training policies."""

    def run(self):
        rc = self.run_cfg
        t = 0.0
        traj_t = self.env.horizon * self.env.dt
        g_alt = max(self.policy_steps // max(self.n_rollouts, 1), 1)
        # initial data
        for _ in range(self.n_rollouts):
            self.collector.step()
            t += traj_t
        while self.collector.collected < rc.total_trajs:
            self.model_worker.stopper.reset()
            for _ in range(self.max_model_epochs):
                out = self.model_worker.step()
                if out is None:
                    break
                t += rc.model_epoch_time
            for _ in range(self.n_rollouts):
                for _ in range(g_alt):
                    if self.policy_worker.step():
                        t += rc.policy_step_time
                self.collector.step()
                t += traj_t
            self._keval, k = jax.random.split(self._keval)
            self.recorder.record(t, self.collector.collected,
                                 self.policy_worker.state["policy"], k)
        return self.recorder.trace
