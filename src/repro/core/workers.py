"""The three workers of Figure 1a, each a pull -> step -> push loop with
the MINIMAL unit of work (one rollout / one model epoch / one policy
gradient step). The same worker objects run either as real threads
(production) or inside the deterministic discrete-event engine
(benchmarks) — see runtime.py. Data collection is a FLEET (ISSUE 5):
any number of ``DataCollectionWorker`` instances — distinct RNG streams
(``collector_key``), pluggable per-collector exploration
(``ExplorationSchedule``), one device each on the collector sub-mesh —
push into the same multi-producer data server.

Hot-path invariants (enforced by tests/test_hotpath.py and
benchmarks/hotpath.py):

* every jitted step function compiles ONCE and never retraces as the
  replay buffer fills (static ring shapes, see servers.ReplayBuffer);
* parameter pulls are version-gated: an unchanged version costs one lock
  + integer compare against a device-resident cache — no host copy, no
  re-upload.

Role meshes (core/roles.py): every worker takes an optional ``mesh`` —
its sub-mesh of the pod. Params live replicated on the owning sub-mesh,
batch-like data is sharded along its leading axis, and cross-role
movement happens only through the placement-aware servers (explicit
device-to-device ``device_put`` on version change). ``mesh=None`` is the
single-device behaviour, bit-for-bit unchanged.

Process isolation (``mode="procs"``, runtime._run_procs): the same
worker objects ALSO run as separate OS processes. The module-level
``proc_worker_main(role, spec, channels)`` entrypoint is picklable
through the spawn context: it rebuilds env/algo/worker from plain
configs (``ProcSpec``) + seed + role inside the child — each child owns
its own jax backend — and talks only through the IPC servers in
``channels`` (ShmParameterServer / ProcDataServer). Cross-process pulls
return host arrays; the pull paths below re-home them onto the worker's
device exactly once per version change, so step loops stay
device-resident in every mode.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import roles as ROLES
from repro.core.servers import DataServer, ParameterServer, ReplayBuffer
from repro.mbrl import dynamics as DYN
from repro.mbrl import policy as PI
from repro.mbrl.early_stop import EMAEarlyStop
from repro.utils.jit_stats import jit_cache_size


def _to_device(tree):
    """Re-home host (np) leaves pulled across a process boundary onto
    this worker's device; jax.Array leaves pass through untouched (the
    in-process servers stay zero-copy)."""
    return jax.tree.map(
        lambda x: x if isinstance(x, jax.Array) else jnp.asarray(x), tree)


@dataclasses.dataclass
class WorkerTimes:
    """Nominal virtual durations (seconds) of each worker's step — used by
    the VirtualClock / discrete-event engine to reproduce the paper's
    real-robot timing (DESIGN.md §2)."""
    trajectory: float       # horizon * env.dt (robot time; exact)
    model_epoch: float = 1.0
    policy_step: float = 0.5


@dataclasses.dataclass(frozen=True)
class ExplorationSchedule:
    """Pluggable per-collector exploration for a fleet (ISSUE 5): each
    collector samples with its own action-noise scale — the paper's
    exploration mechanism fanned out heterogeneously, like the
    multi-robot setup of Gu et al. (2016). Scales cycle when the fleet
    is larger than the tuple; scale 1.0 is exactly the single-collector
    behaviour. Plain frozen dataclass of floats: picklable through the
    spawn boundary (``ProcSpec``)."""
    noise_scales: tuple = (1.0,)

    def scale_for(self, collector_id: int) -> float:
        return float(self.noise_scales[collector_id
                                       % len(self.noise_scales)])

    @classmethod
    def ladder(cls, n_collectors: int, lo: float = 0.75,
               hi: float = 1.5) -> "ExplorationSchedule":
        """Evenly spaced lo..hi noise ladder across the fleet; collector
        0 keeps scale 1.0 so its stream stays comparable to a lone
        collector. A two-collector fleet gets (1.0, hi): with one varied
        rung, the wider-exploring endpoint is the one worth adding."""
        if n_collectors <= 1:
            return cls((1.0,))
        k = n_collectors - 1            # varied rungs
        if k == 1:
            return cls((1.0, hi))
        rest = tuple(lo + (hi - lo) * i / (k - 1) for i in range(k))
        return cls((1.0,) + rest)


def collector_key(key, collector_id: int):
    """Per-collector RNG stream: collector 0 keeps the engine's base
    collector key UNTOUCHED (so a fleet of one is bit-identical to the
    pre-fleet engine); every other collector folds its id in."""
    return key if collector_id == 0 else jax.random.fold_in(
        key, collector_id)


def heartbeat_slot(role: str, n_collectors: int = 1) -> int:
    """Index of ``role``'s slot in the shared heartbeat array (see
    ProcChannels.heartbeat): model=0, policy=1, collector:<i>=2+i."""
    if role == "model":
        return 0
    if role == "policy":
        return 1
    cid = int(role.split(":", 1)[1]) if ":" in role else 0
    return 2 + (cid % max(int(n_collectors), 1))


def heartbeat_slots(n_collectors: int) -> int:
    """Total heartbeat slots for a run: model + policy + the fleet."""
    return 2 + max(int(n_collectors), 1)


def default_burst(n_collectors: int, envs_per_step: int = 1) -> int:
    """Drain burst capacity for a fleet of N collectors running B envs
    each: the one heuristic shared by the in-process engines and the
    procs-mode child model worker. An env farm's whole batch must fit
    one burst so its drain stays a single compiled scatter per chunk."""
    return max(8, 2 * int(n_collectors), int(envs_per_step))


# One compiled rollout program per (env value, noise scale, batch size)
# — N same-scale fleet members share a single trace/compile instead of
# paying N identical ones (envs are small frozen dataclasses, so
# value-equal envs share). BOUNDED exactly like runtime._EVAL_CACHE
# (ISSUE 6 satellite): plain dict in insertion order, pop + reinsert on
# hit = LRU touch, oldest evicted past _ROLLOUT_CACHE_MAX — bench
# sweeps over noise scales / batch sizes can no longer grow it without
# limit, and an evicted entry strands nothing (each worker holds its
# own fn, which stays valid standalone). Batch size None keys the
# single-trajectory program; an int keys the B-lane farm program.
_ROLLOUT_CACHE: Dict[Any, Callable] = {}
_ROLLOUT_CACHE_MAX = 64


def clear_rollout_cache() -> None:
    """Drop every cached compiled rollout, single and batched.
    Benchmarks call this between sweep groups."""
    _ROLLOUT_CACHE.clear()


def _rollout_cache_put(cache_key, build: Callable) -> Callable:
    fn = _ROLLOUT_CACHE.pop(cache_key, None)    # pop + reinsert = LRU
    if fn is None:
        fn = build()
    _ROLLOUT_CACHE[cache_key] = fn
    while len(_ROLLOUT_CACHE) > _ROLLOUT_CACHE_MAX:  # dicts iterate in
        del _ROLLOUT_CACHE[next(iter(_ROLLOUT_CACHE))]  # insertion order
    return fn


def _sampler_for(noise_scale: float):
    if noise_scale == 1.0:
        return PI.sample_action         # bit-identical lone-collector
    #                                     path, and no spurious * 1.0

    def sampler(p, s, k):
        return PI.sample_action_scaled(p, s, k, noise_scale)
    return sampler


def _rollout_jit(env, noise_scale: float):
    """Compiled single-trajectory rollout for (env value, noise scale).
    Per-device executables are jax's own cache, keyed on placement."""
    sampler = _sampler_for(noise_scale)
    return _rollout_cache_put(
        (env, float(noise_scale), None),
        lambda: jax.jit(lambda p, k: env.rollout(k, sampler, p)))


def _rollout_batch_jit(env, noise_scale: float, n: int):
    """Compiled B-lane farm rollout for (env value, noise scale, B) —
    one vmapped scan per batch size, compiled once and shared across
    same-shape claimers (a partial batch of g < B lanes hits the same
    cache entry as a worker whose full batch IS g, so the two produce
    identical trajectories from identical keys)."""
    sampler = _sampler_for(noise_scale)
    n = int(n)
    return _rollout_cache_put(
        (env, float(noise_scale), n),
        lambda: jax.jit(
            lambda p, k: env.rollout_batch(k, sampler, p, n)))


class DataCollectionWorker:
    """Algorithm 1. Pull policy θ -> collect a batch of trajectories ->
    push (``envs_per_step=1``, the default, collects exactly ONE — the
    pre-farm worker, bit for bit).

    The pull is version-gated: the worker keeps a device-resident policy
    cache and only swaps it when the server holds a newer version.

    Fleet-aware: ``collector_id`` selects this collector's RNG stream,
    its device within the collector sub-mesh (round-robin, see
    ``roles.collector_sharding``), and — via ``noise_scale`` — its rung
    on the fleet's exploration schedule.

    Farm-aware (ISSUE 6): ``envs_per_step=B`` makes every ``step``
    simulate B robots via one vmapped rollout (``Env.rollout_batch``,
    one compile per (env, noise, B)) and push all B trajectories as one
    stacked batch. The worker splits its key ONCE per step regardless
    of B — lane streams are derived inside the batch program
    (``envs.base.lane_keys``: lane 0 keeps the step key) — so the B=1
    stream is exactly the pre-farm stream."""

    def __init__(self, env, policy_server: ParameterServer,
                 data_server: DataServer, init_policy_params, key,
                 *, speed: float = 1.0, mesh=None, collector_id: int = 0,
                 noise_scale: float = 1.0, envs_per_step: int = 1):
        """``init_policy_params=None`` (procs mode): the collector has no
        in-process policy worker to borrow initial params from — it idles
        (``step`` returns None) until the policy process publishes
        version 1."""
        self.env = env
        self.policy_server = policy_server
        self.data_server = data_server
        self.collector_id = int(collector_id)
        self.noise_scale = float(noise_scale)
        self.envs_per_step = int(envs_per_step)
        if self.envs_per_step < 1:
            raise ValueError(f"envs_per_step must be >= 1, got "
                             f"{self.envs_per_step}")
        self._key = collector_key(key, self.collector_id)
        self._policy_cache = (None if init_policy_params is None else
                              jax.tree.map(jnp.asarray, init_policy_params))
        self._policy_ver = 0
        self.speed = speed  # >1: faster collection (Fig. 5b)
        self.collected = 0
        # each collector is a sequential control loop (one robot): it
        # runs on ONE device of the collector sub-mesh; a fleet spreads
        # round-robin across the sub-mesh's devices, pulls land there
        self._sharding = None
        if mesh is not None:
            self._sharding = ROLES.collector_sharding(mesh,
                                                      self.collector_id)
            if self._policy_cache is not None:
                self._policy_cache = jax.device_put(self._policy_cache,
                                                    self._sharding)
        # B=1 keeps the SINGLE-rollout compiled program (bit-identity
        # with the pre-farm engine); B>1 holds its own farm program so
        # cache eviction can't cost a recompile mid-run
        self._rollout = _rollout_jit(env, self.noise_scale)
        self._rollout_batch = (
            None if self.envs_per_step == 1 else
            _rollout_batch_jit(env, self.noise_scale, self.envs_per_step))

    def compile_count(self) -> int:
        """Compiled-program entries across this collector's OWN rollout
        jits (liveness/invariant telemetry for the chaos monitor): the
        single-rollout program plus — for a farm — its full-B program.
        Steady state is 1 (B=1) or at most 2 (B>1: the full batch, plus
        the single-rollout variant a final grant of g=1 may touch);
        anything above means a retrace. -1 when jax hides the caches."""
        fns = [self._rollout] + (
            [] if self._rollout_batch is None else [self._rollout_batch])
        sizes = [jit_cache_size(f) for f in fns]
        return -1 if any(s < 0 for s in sizes) else sum(sizes)

    def poll_policy(self) -> bool:
        """Refresh the policy cache (version-gated) WITHOUT collecting.
        True once a policy is available — procs-mode collectors spin on
        this during warmup so a claimed collection slot is always
        fulfilled by the following ``step``."""
        fresh, self._policy_ver = self.policy_server.pull_if_newer(
            self._policy_ver, sharding=self._sharding)
        if fresh is not None:
            self._policy_cache = _to_device(fresh)
        return self._policy_cache is not None

    def step(self, n: Optional[int] = None) -> Optional[float]:
        """One batch of ``n`` trajectories (default: the worker's full
        ``envs_per_step``); returns its robot-time duration, or None
        when no policy has been published yet (procs-mode warmup).

        ``n < envs_per_step`` runs a PARTIAL batch through a smaller
        compiled variant — the engines pass the ticket grant here when
        fewer than B slots remain toward the global criterion, so the
        run lands exactly on ``total_trajs`` (at most one extra compile,
        at the very end of a run). The batch simulates n robots in
        PARALLEL, so the robot-time duration is one trajectory's
        regardless of n."""
        if not self.poll_policy():                      # Pull (gated)
            return None
        g = self.envs_per_step if n is None else int(n)
        # ONE key split per step whatever g is: the B=1 stream is the
        # pre-farm stream, and lanes derive inside the batch program
        self._key, k = jax.random.split(self._key)
        if g == 1:
            traj = self._rollout(self._policy_cache, k)     # Step
            self.data_server.push(traj,
                                  collector_id=self.collector_id)  # Push
        else:
            fn = (self._rollout_batch if g == self.envs_per_step
                  else _rollout_batch_jit(self.env, self.noise_scale, g))
            batch = fn(self._policy_cache, k)               # Step (farm)
            self.data_server.push_batch(
                batch, g, collector_id=self.collector_id)   # Push
        self.collected += g
        return (self.env.horizon * self.env.dt) / self.speed


class ModelLearningWorker:
    """Algorithm 2. Drain data -> one epoch on the local FIFO ring buffer
    (with EMA-validation early stopping, §5.4) -> push φ.

    Storage is a preallocated :class:`ReplayBuffer`; the trainer is built
    lazily on first data (capacity = max_trajs * horizon) and after that
    every epoch runs the same compiled program — no retrace as the buffer
    fills, no per-epoch concatenate, params/opt_state donated."""

    def __init__(self, ens_cfg: DYN.EnsembleConfig,
                 data_server: DataServer, model_server: ParameterServer,
                 key, *, max_trajs: int = 200, ema_weight: float = 0.9,
                 early_stop: bool = True, min_trajs: int = 4,
                 mesh=None, batch_axis: Optional[str] = None,
                 burst: int = 8):
        """``burst``: ring-write burst capacity — a drain of M
        trajectories (a fleet pushes many between epochs) lands in
        ceil(M/burst) compiled scatters instead of M."""
        self.cfg = ens_cfg
        self.data_server = data_server
        self.model_server = model_server
        self.max_trajs = max_trajs
        self.burst = max(int(burst), 1)
        self.buffer: Optional[ReplayBuffer] = None    # lazy: needs horizon
        self._key, k0 = jax.random.split(key)
        self.params = DYN.init_ensemble(ens_cfg, k0)
        # role sub-mesh: ensemble trains data-parallel — ring storage
        # sharded over the batch axis, params/opt_state replicated
        self._repl = self._batch_shard = None
        if mesh is not None:
            self._repl = ROLES.replicated(mesh)
            self._batch_shard = ROLES.batch_sharded(mesh, batch_axis)
            self.params = jax.device_put(self.params, self._repl)
        self._train_epoch = None
        self._val_loss = None
        self._update_norm = None
        self.opt_state = None
        self.stopper = EMAEarlyStop(weight=ema_weight, enabled=early_stop)
        self.epochs = 0
        self._have_data = False
        # the policy worker blocks on the model server, so deferring the
        # first push until a small initial dataset exists reproduces the
        # paper's 'acquire an initial dataset' phase (§5.3)
        self.min_trajs = min_trajs

    def _ensure_trainer(self, traj) -> None:
        if self.buffer is not None:
            return
        horizon = int(jax.tree.leaves(traj)[0].shape[0])
        capacity = self.max_trajs * horizon
        # ReplayBuffer rounds a sharded capacity up to the shard count
        # itself; read the final value back for the trainer's grid
        self.buffer = ReplayBuffer(capacity, sharding=self._batch_shard,
                                   burst_capacity=self.burst)
        opt, self._train_epoch, self._val_loss, self._update_norm = \
            DYN.make_ring_trainer(self.cfg, self.buffer.capacity,
                                  batch_sharding=self._batch_shard)
        self.opt_state = opt.init(self.params)

    def compile_count(self) -> int:
        """Traces of the ring ``train_epoch`` (exact, via TraceCounted).
        The PR 1 invariant says this is 1 for the whole life of the
        worker once data exists — the chaos monitor asserts it DURING
        soak runs, per child incarnation."""
        return jit_cache_size(self._train_epoch)

    def _refresh_data(self) -> bool:
        new = self.data_server.drain()                  # Pull (move all)
        if new:
            self._ensure_trainer(new[0])
            self.buffer.extend(new)
            self._have_data = True
            self.stopper.reset()                        # §4: resume training
        return bool(new)

    def step(self) -> Optional[float]:
        """One epoch; returns None when idle (no data / early-stopped)."""
        self._refresh_data()
        if not self._have_data or self.buffer.total_seen < self.min_trajs:
            return None
        if self.stopper.stopped:
            return None
        data, size = self.buffer.train_view()
        self.params = {**self.params,
                       "norm": self._update_norm(data, size)}
        self._key, k = jax.random.split(self._key)
        self.params, self.opt_state, tr_loss = self._train_epoch(
            self.params, self.opt_state, data, size, k)
        vdata, vsize = self.buffer.val_view()
        if vsize == 0:
            # no held-out traj yet: validate on a val-ring-SHAPED slice
            # of the train ring, so _val_loss still compiles only once
            vcap = self.buffer.val_capacity
            vdata = {k: v[:vcap] for k, v in data.items()}
            vsize = min(size, vcap)
        vloss = float(self._val_loss(self.params, vdata, vsize))
        self.stopper.update(vloss)
        self.epochs += 1
        self.model_server.push(self.params)             # Push
        return vloss


class PolicyImprovementWorker:
    """Algorithm 3. Pull φ -> ONE policy-improvement step (TRPO/PPO/MB-MPO
    on imagined rollouts) -> push θ.

    Keeps a device-resident model cache; an unchanged model version
    costs one lock + integer compare."""

    def __init__(self, algo, policy_server: ParameterServer,
                 model_server: ParameterServer, key, *, mesh=None,
                 batch_axis: Optional[str] = None, push_init: bool = True):
        """``push_init=False`` (procs-mode crash restart): suppress the
        initial random-policy push so a restarted worker can first load
        the latest snapshot and publish THAT instead — collectors never
        see a regression to fresh init params."""
        self.algo = algo
        self.policy_server = policy_server
        self.model_server = model_server
        self._key, k0 = jax.random.split(key)
        # role sub-mesh: imagination rollouts + TRPO batch statistics are
        # sharded over the policy sub-mesh; policy/model params replicated
        self._repl = None
        if mesh is not None:
            self._repl = ROLES.replicated(mesh)
            if hasattr(algo, "configure_mesh"):
                algo.configure_mesh(mesh, batch_axis)
        self.state = algo.init(k0)
        if self._repl is not None:
            self.state = jax.device_put(self.state, self._repl)
        if push_init:
            self.policy_server.push(self.state["policy"])
        self._model_cache = None
        self._model_ver = 0
        self.steps = 0

    def compile_count(self) -> int:
        """Compiled entries of the algo's one fused ``_improve`` jit
        (static shapes: steady state is exactly 1). -1 when the algo
        doesn't expose it — the chaos monitor then skips the check."""
        fn = getattr(self.algo, "_improve", None)
        return jit_cache_size(fn) if fn is not None else -1

    def step(self) -> bool:
        fresh, self._model_ver = self.model_server.pull_if_newer(
            self._model_ver, sharding=self._repl)       # Pull (gated)
        if fresh is not None:
            self._model_cache = _to_device(fresh)
        if self._model_cache is None:
            return False
        self._key, k = jax.random.split(self._key)
        self.state, info = self.algo.improve(self.state, self._model_cache,
                                             k)
        self.steps += 1
        self.policy_server.push(self.state["policy"])   # Push
        return True


# --------------------------------------------------------------- procs mode
#
# The paper's actual deployment shape: collector, model learner and
# policy improver as SEPARATE OS PROCESSES, so model/policy compute
# cannot steal cycles from the (real-time) collector even under the GIL.
# Everything below must stay picklable through the spawn context:
# plain-config dataclasses in, module-level entrypoint, IPC servers from
# servers.py. Heavy objects (env rollout jits, algos, ensembles) are
# REBUILT inside the child from ``(cfg, seed, role)``.

@dataclasses.dataclass
class ProcSpec:
    """Everything a spawned worker needs to rebuild its role locally:
    plain-dataclass configs + the shared seed. The child derives the
    same per-role keys as the in-process engines (split(key(seed), 4);
    fleet collectors additionally fold their id in — see
    ``collector_key``). Also the JOIN payload of the tcp transport:
    the parent publishes a pickled ProcSpec on its ControlPlane and a
    ``--connect`` joiner rebuilds a collector from it (net/join.py)."""
    env: Any                    # frozen env dataclass (picklable)
    ens_cfg: DYN.EnsembleConfig
    algo_cfg: Any               # mbrl.AlgoConfig
    pol_cfg: PI.PolicyConfig
    run_cfg: Any                # core.RunConfig
    seed: int
    exploration: Any = None     # ExplorationSchedule (or None: all 1.0)


@dataclasses.dataclass
class ProcChannels:
    """IPC endpoints shared by all three worker processes. The server
    handles are transport-blind (servers.ParameterTransport /
    DataTransport): shm/mp servers or tcp clients pickle through spawn
    identically, and the worker loops never know which they hold."""
    model_server: Any           # ParameterTransport (written by model)
    policy_server: Any          # ParameterTransport (written by policy)
    data: Any                   # DataTransport (collector -> model)
    trace_q: Any                # mp.Queue: eval-trace rows -> parent
    stop: Any                   # mp.Event: parent-ordered shutdown
    t0: float                   # parent's monotonic run start (shared
    #                             CLOCK_MONOTONIC: rows are run-relative)
    # liveness + invariant telemetry (chaos/soak, PR 7): a lock-free
    # mp.Array('d') of 2 doubles per heartbeat_slot — [last beat
    # monotonic, worker compile_count]. Single writer per slot (the
    # role's child); aligned 8-byte stores, so the parent's monitor
    # reads are never torn in practice. None = telemetry off (every
    # pre-chaos caller), all beats no-ops.
    heartbeat: Any = None

    def beat(self, slot: int, compiles: int = -1) -> None:
        """One worker-loop heartbeat: stamp the clock and publish the
        worker's current compile count. Cheap enough for every loop
        iteration (two array stores, no lock)."""
        hb = self.heartbeat
        if hb is None:
            return
        hb[2 * slot] = time.monotonic()
        hb[2 * slot + 1] = float(compiles)

    def read_heartbeat(self, slot: int):
        """(last_beat_monotonic, compile_count) for one slot — parent
        side. (0.0, 0.0) until the child's first beat."""
        hb = self.heartbeat
        if hb is None:
            return 0.0, 0.0
        return float(hb[2 * slot]), float(hb[2 * slot + 1])


def _load_snapshot(resume_dir, spec):
    """Latest COMPLETE parent snapshot as (tree, step) or (None, None).
    The template is rebuilt from configs via eval_shape — no device
    work. Corruption-tolerant: ``restore`` already skips truncated
    snapshots (newest-complete-first), and if NOTHING under the dir
    loads, a restarting worker starts fresh instead of crash-looping on
    a poisoned checkpoint (restart-under-fire, PR 7)."""
    import numpy as np

    from repro.checkpoint import io as ckpt_io
    if resume_dir is None or ckpt_io.latest_step(resume_dir) is None:
        return None, None
    template = {
        "model": jax.eval_shape(
            lambda: DYN.init_ensemble(spec.ens_cfg, jax.random.key(0))),
        "model_version": jax.ShapeDtypeStruct((), np.int64),
        "policy": jax.eval_shape(
            lambda: PI.init_policy(spec.pol_cfg, jax.random.key(0))),
        "policy_version": jax.ShapeDtypeStruct((), np.int64),
    }
    try:
        return ckpt_io.restore(resume_dir, template)
    except Exception:
        return None, None


def _proc_collector(spec, ch, key, collector_id: int = 0):
    rc = spec.run_cfg
    sched = spec.exploration or ExplorationSchedule()
    slot = heartbeat_slot(f"collector:{collector_id}", rc.n_collectors)
    w = DataCollectionWorker(spec.env, ch.policy_server, ch.data, None,
                             key, speed=rc.collect_speed,
                             collector_id=collector_id,
                             noise_scale=sched.scale_for(collector_id),
                             envs_per_step=rc.envs_per_collector)
    # warmup: don't claim a collection slot until a policy exists — a
    # claimed ticket must always be fulfilled by the very next step, or
    # the fleet's exact stopping criterion would stall on it
    while not ch.stop.is_set() and not w.poll_policy():
        ch.beat(slot, w.compile_count())
        time.sleep(0.005)
    # restart-safe stopping criterion: tickets live in the shared
    # ProcDataServer, so a restarted collector resumes the GLOBAL count
    # (the parent refunds the tickets of a crash-interrupted batch)
    while not ch.stop.is_set():
        ch.beat(slot, w.compile_count())
        g = ch.data.try_claim(collector_id, k=w.envs_per_step)
        if not g:
            break                   # global target fully claimed: done
        t_step = time.monotonic()
        try:
            dur = w.step(g)
        except Exception:
            if ch.stop.is_set():    # queue torn down mid-push: clean exit
                break
            raise
        if rc.pace_collection and dur is not None:
            # robot control frequency: one trajectory occupies `dur`
            # seconds of real time however fast the simulation computes
            time.sleep(max(dur - (time.monotonic() - t_step), 0.0))
    ch.beat(slot, w.compile_count())


def _proc_model(spec, ch, key, resume_dir):
    rc = spec.run_cfg
    w = ModelLearningWorker(spec.ens_cfg, ch.data, ch.model_server, key,
                            ema_weight=rc.ema_weight,
                            early_stop=rc.early_stop,
                            min_trajs=rc.min_warmup_trajs,
                            burst=default_burst(rc.n_collectors,
                                                rc.envs_per_collector))
    snap, _ = _load_snapshot(resume_dir, spec)
    if snap is not None:
        # crash restart: resume from the parent's latest checkpoint and
        # republish immediately — the policy worker sees a model version
        # NEWER than at crash time instead of waiting out a re-warmup.
        # (Optimizer state restarts fresh; the ring buffer refills from
        # the live trajectory queue.)
        w.params = _to_device(snap["model"])
        ch.model_server.push(w.params)
    slot = heartbeat_slot("model", rc.n_collectors)
    while not ch.stop.is_set():
        ch.beat(slot, w.compile_count())
        if w.step() is None:
            time.sleep(0.002)
    ch.beat(slot, w.compile_count())


def _proc_policy(spec, ch, key, keval, resume_dir):
    from repro.core.runtime import _Recorder
    from repro.mbrl.algos import make_algo
    rc = spec.run_cfg
    algo = make_algo(spec.algo_cfg, spec.pol_cfg,
                     jax.vmap(spec.env.reward), spec.env.reset_batch)
    # push_init=False: on a crash restart the snapshot policy must be
    # published FIRST — collectors never regress to fresh init params
    w = PolicyImprovementWorker(algo, ch.policy_server, ch.model_server,
                                key, push_init=False)
    snap, _ = _load_snapshot(resume_dir, spec)
    if snap is not None:
        w.state = {**w.state, "policy": _to_device(snap["policy"])}
    w.policy_server.push(w.state["policy"])
    rec = _Recorder(spec.env, rc.eval_rollouts)

    def record():
        nonlocal keval
        keval, k = jax.random.split(keval)
        rec.record(time.monotonic() - ch.t0, ch.data.total_pushed,
                   w.state["policy"], k)
        ch.trace_q.put(rec.trace[-1])

    slot = heartbeat_slot("policy", rc.n_collectors)
    n = 0
    while not ch.stop.is_set():
        ch.beat(slot, w.compile_count())
        if w.step():
            n += 1
            if n % rc.eval_every_policy_steps == 0:
                record()
        else:
            time.sleep(0.002)
    ch.beat(slot, w.compile_count())
    record()                        # final eval at shutdown


def proc_worker_main(role: str, spec: ProcSpec, ch: ProcChannels,
                     resume_dir: Optional[str] = None) -> None:
    """Picklable child entrypoint (spawn context). Each child initialises
    its OWN jax backend on import — nothing jax crosses the process
    boundary except host arrays through the IPC servers. Fleet
    collectors are addressed ``"collector:<id>"``; the id picks the
    collector's RNG stream and exploration rung."""
    key = jax.random.key(spec.seed)
    _kc, _km, _kp, _keval = jax.random.split(key, 4)
    try:
        if role == "collector" or role.startswith("collector:"):
            cid = int(role.split(":", 1)[1]) if ":" in role else 0
            _proc_collector(spec, ch, _kc, cid)
        elif role == "model":
            _proc_model(spec, ch, _km, resume_dir)
        elif role == "policy":
            _proc_policy(spec, ch, _kp, _keval, resume_dir)
        else:
            raise ValueError(f"unknown role {role!r}")
    except KeyboardInterrupt:
        pass
    finally:
        # drop this child's shm mappings cleanly (non-owners never
        # unlink); otherwise cached np views make the interpreter-exit
        # __del__ spray BufferErrors
        for srv in (ch.model_server, ch.policy_server):
            try:
                srv.close()
            except Exception:
                pass
