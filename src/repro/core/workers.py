"""The three workers of Figure 1a, each a pull -> step -> push loop with
the MINIMAL unit of work (one rollout / one model epoch / one policy
gradient step). The same worker objects run either as real threads
(production) or inside the deterministic discrete-event engine
(benchmarks) — see runtime.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.servers import DataServer, LocalBuffer, ParameterServer
from repro.mbrl import dynamics as DYN
from repro.mbrl import policy as PI
from repro.mbrl.early_stop import EMAEarlyStop


@dataclasses.dataclass
class WorkerTimes:
    """Nominal virtual durations (seconds) of each worker's step — used by
    the VirtualClock / discrete-event engine to reproduce the paper's
    real-robot timing (DESIGN.md §2)."""
    trajectory: float       # horizon * env.dt (robot time; exact)
    model_epoch: float = 1.0
    policy_step: float = 0.5


class DataCollectionWorker:
    """Algorithm 1. Pull policy θ -> collect ONE trajectory -> push."""

    def __init__(self, env, policy_server: ParameterServer,
                 data_server: DataServer, init_policy_params, key,
                 *, speed: float = 1.0):
        self.env = env
        self.policy_server = policy_server
        self.data_server = data_server
        self._key = key
        self._fallback = jax.tree.map(np.asarray, init_policy_params)
        self.speed = speed  # >1: faster collection (Fig. 5b)
        self.collected = 0
        self._rollout = jax.jit(
            lambda p, k: env.rollout(k, PI.sample_action, p))

    def step(self) -> float:
        params, _ = self.policy_server.pull()           # Pull
        if params is None:
            params = self._fallback
        self._key, k = jax.random.split(self._key)
        traj = self._rollout(params, k)                 # Step
        self.data_server.push(traj)                     # Push
        self.collected += 1
        return (self.env.horizon * self.env.dt) / self.speed


class ModelLearningWorker:
    """Algorithm 2. Drain data -> one epoch on the local FIFO buffer (with
    EMA-validation early stopping, §5.4) -> push φ."""

    def __init__(self, ens_cfg: DYN.EnsembleConfig,
                 data_server: DataServer, model_server: ParameterServer,
                 key, *, max_trajs: int = 200, ema_weight: float = 0.9,
                 early_stop: bool = True, min_trajs: int = 4):
        self.cfg = ens_cfg
        self.data_server = data_server
        self.model_server = model_server
        self.buffer = LocalBuffer(max_trajs=max_trajs)
        self._key, k0 = jax.random.split(key)
        self.params = DYN.init_ensemble(ens_cfg, k0)
        opt, self._train_epoch, self._val_loss = DYN.make_model_trainer(
            ens_cfg)
        self.opt_state = opt.init(self.params)
        self.stopper = EMAEarlyStop(weight=ema_weight, enabled=early_stop)
        self.epochs = 0
        self._have_data = False
        # the policy worker blocks on the model server, so deferring the
        # first push until a small initial dataset exists reproduces the
        # paper's 'acquire an initial dataset' phase (§5.3)
        self.min_trajs = min_trajs

    def _refresh_data(self) -> bool:
        new = self.data_server.drain()                  # Pull (move all)
        if new:
            self.buffer.extend(new)
            self._have_data = True
            self.stopper.reset()                        # §4: resume training
        return bool(new)

    def step(self) -> Optional[float]:
        """One epoch; returns None when idle (no data / early-stopped)."""
        self._refresh_data()
        if not self._have_data or self.buffer.total_seen < self.min_trajs:
            return None
        if self.stopper.stopped:
            return None
        data = self.buffer.train_arrays()
        val = self.buffer.val_arrays()
        self.params = DYN.update_normalizer(
            self.params, data["obs"], data["act"], data["next_obs"])
        self._key, k = jax.random.split(self._key)
        self.params, self.opt_state, tr_loss = self._train_epoch(
            self.params, self.opt_state, data["obs"], data["act"],
            data["next_obs"], k)
        vloss = float(self._val_loss(self.params, val["obs"], val["act"],
                                     val["next_obs"]))
        self.stopper.update(vloss)
        self.epochs += 1
        self.model_server.push(self.params)             # Push
        return vloss


class PolicyImprovementWorker:
    """Algorithm 3. Pull φ -> ONE policy-improvement step (TRPO/PPO/MB-MPO
    on imagined rollouts) -> push θ."""

    def __init__(self, algo, policy_server: ParameterServer,
                 model_server: ParameterServer, key):
        self.algo = algo
        self.policy_server = policy_server
        self.model_server = model_server
        self._key, k0 = jax.random.split(key)
        self.state = algo.init(k0)
        self.policy_server.push(self.state["policy"])
        self.steps = 0

    def step(self) -> bool:
        model_params, ver = self.model_server.pull()    # Pull
        if model_params is None:
            return False
        self._key, k = jax.random.split(self._key)
        self.state, info = self.algo.improve(self.state, model_params, k)
        self.steps += 1
        self.policy_server.push(self.state["policy"])   # Push
        return True
