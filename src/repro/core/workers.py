"""The three workers of Figure 1a, each a pull -> step -> push loop with
the MINIMAL unit of work (one rollout / one model epoch / one policy
gradient step). The same worker objects run either as real threads
(production) or inside the deterministic discrete-event engine
(benchmarks) — see runtime.py.

Hot-path invariants (enforced by tests/test_hotpath.py and
benchmarks/hotpath.py):

* every jitted step function compiles ONCE and never retraces as the
  replay buffer fills (static ring shapes, see servers.ReplayBuffer);
* parameter pulls are version-gated: an unchanged version costs one lock
  + integer compare against a device-resident cache — no host copy, no
  re-upload.

Role meshes (core/roles.py): every worker takes an optional ``mesh`` —
its sub-mesh of the pod. Params live replicated on the owning sub-mesh,
batch-like data is sharded along its leading axis, and cross-role
movement happens only through the placement-aware servers (explicit
device-to-device ``device_put`` on version change). ``mesh=None`` is the
single-device behaviour, bit-for-bit unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import roles as ROLES
from repro.core.servers import DataServer, ParameterServer, ReplayBuffer
from repro.mbrl import dynamics as DYN
from repro.mbrl import policy as PI
from repro.mbrl.early_stop import EMAEarlyStop


@dataclasses.dataclass
class WorkerTimes:
    """Nominal virtual durations (seconds) of each worker's step — used by
    the VirtualClock / discrete-event engine to reproduce the paper's
    real-robot timing (DESIGN.md §2)."""
    trajectory: float       # horizon * env.dt (robot time; exact)
    model_epoch: float = 1.0
    policy_step: float = 0.5


class DataCollectionWorker:
    """Algorithm 1. Pull policy θ -> collect ONE trajectory -> push.

    The pull is version-gated: the worker keeps a device-resident policy
    cache and only swaps it when the server holds a newer version."""

    def __init__(self, env, policy_server: ParameterServer,
                 data_server: DataServer, init_policy_params, key,
                 *, speed: float = 1.0, mesh=None):
        self.env = env
        self.policy_server = policy_server
        self.data_server = data_server
        self._key = key
        self._policy_cache = jax.tree.map(jnp.asarray, init_policy_params)
        self._policy_ver = 0
        self.speed = speed  # >1: faster collection (Fig. 5b)
        self.collected = 0
        # the collector is a sequential control loop (the robot): it runs
        # on ONE device of its sub-mesh; pulls land there directly
        self._sharding = None
        if mesh is not None:
            self._sharding = jax.sharding.SingleDeviceSharding(
                mesh.devices.flat[0])
            self._policy_cache = jax.device_put(self._policy_cache,
                                                self._sharding)
        self._rollout = jax.jit(
            lambda p, k: env.rollout(k, PI.sample_action, p))

    def step(self) -> float:
        fresh, self._policy_ver = self.policy_server.pull_if_newer(
            self._policy_ver, sharding=self._sharding)  # Pull (gated)
        if fresh is not None:
            self._policy_cache = fresh
        self._key, k = jax.random.split(self._key)
        traj = self._rollout(self._policy_cache, k)     # Step
        self.data_server.push(traj)                     # Push
        self.collected += 1
        return (self.env.horizon * self.env.dt) / self.speed


class ModelLearningWorker:
    """Algorithm 2. Drain data -> one epoch on the local FIFO ring buffer
    (with EMA-validation early stopping, §5.4) -> push φ.

    Storage is a preallocated :class:`ReplayBuffer`; the trainer is built
    lazily on first data (capacity = max_trajs * horizon) and after that
    every epoch runs the same compiled program — no retrace as the buffer
    fills, no per-epoch concatenate, params/opt_state donated."""

    def __init__(self, ens_cfg: DYN.EnsembleConfig,
                 data_server: DataServer, model_server: ParameterServer,
                 key, *, max_trajs: int = 200, ema_weight: float = 0.9,
                 early_stop: bool = True, min_trajs: int = 4,
                 mesh=None, batch_axis: Optional[str] = None):
        self.cfg = ens_cfg
        self.data_server = data_server
        self.model_server = model_server
        self.max_trajs = max_trajs
        self.buffer: Optional[ReplayBuffer] = None    # lazy: needs horizon
        self._key, k0 = jax.random.split(key)
        self.params = DYN.init_ensemble(ens_cfg, k0)
        # role sub-mesh: ensemble trains data-parallel — ring storage
        # sharded over the batch axis, params/opt_state replicated
        self._repl = self._batch_shard = None
        if mesh is not None:
            self._repl = ROLES.replicated(mesh)
            self._batch_shard = ROLES.batch_sharded(mesh, batch_axis)
            self.params = jax.device_put(self.params, self._repl)
        self._train_epoch = None
        self._val_loss = None
        self._update_norm = None
        self.opt_state = None
        self.stopper = EMAEarlyStop(weight=ema_weight, enabled=early_stop)
        self.epochs = 0
        self._have_data = False
        # the policy worker blocks on the model server, so deferring the
        # first push until a small initial dataset exists reproduces the
        # paper's 'acquire an initial dataset' phase (§5.3)
        self.min_trajs = min_trajs

    def _ensure_trainer(self, traj) -> None:
        if self.buffer is not None:
            return
        horizon = int(jax.tree.leaves(traj)[0].shape[0])
        capacity = self.max_trajs * horizon
        # ReplayBuffer rounds a sharded capacity up to the shard count
        # itself; read the final value back for the trainer's grid
        self.buffer = ReplayBuffer(capacity, sharding=self._batch_shard)
        opt, self._train_epoch, self._val_loss, self._update_norm = \
            DYN.make_ring_trainer(self.cfg, self.buffer.capacity,
                                  batch_sharding=self._batch_shard)
        self.opt_state = opt.init(self.params)

    def _refresh_data(self) -> bool:
        new = self.data_server.drain()                  # Pull (move all)
        if new:
            self._ensure_trainer(new[0])
            self.buffer.extend(new)
            self._have_data = True
            self.stopper.reset()                        # §4: resume training
        return bool(new)

    def step(self) -> Optional[float]:
        """One epoch; returns None when idle (no data / early-stopped)."""
        self._refresh_data()
        if not self._have_data or self.buffer.total_seen < self.min_trajs:
            return None
        if self.stopper.stopped:
            return None
        data, size = self.buffer.train_view()
        self.params = {**self.params,
                       "norm": self._update_norm(data, size)}
        self._key, k = jax.random.split(self._key)
        self.params, self.opt_state, tr_loss = self._train_epoch(
            self.params, self.opt_state, data, size, k)
        vdata, vsize = self.buffer.val_view()
        if vsize == 0:
            # no held-out traj yet: validate on a val-ring-SHAPED slice
            # of the train ring, so _val_loss still compiles only once
            vcap = self.buffer.val_capacity
            vdata = {k: v[:vcap] for k, v in data.items()}
            vsize = min(size, vcap)
        vloss = float(self._val_loss(self.params, vdata, vsize))
        self.stopper.update(vloss)
        self.epochs += 1
        self.model_server.push(self.params)             # Push
        return vloss


class PolicyImprovementWorker:
    """Algorithm 3. Pull φ -> ONE policy-improvement step (TRPO/PPO/MB-MPO
    on imagined rollouts) -> push θ.

    Keeps a device-resident model cache; an unchanged model version
    costs one lock + integer compare."""

    def __init__(self, algo, policy_server: ParameterServer,
                 model_server: ParameterServer, key, *, mesh=None,
                 batch_axis: Optional[str] = None):
        self.algo = algo
        self.policy_server = policy_server
        self.model_server = model_server
        self._key, k0 = jax.random.split(key)
        # role sub-mesh: imagination rollouts + TRPO batch statistics are
        # sharded over the policy sub-mesh; policy/model params replicated
        self._repl = None
        if mesh is not None:
            self._repl = ROLES.replicated(mesh)
            if hasattr(algo, "configure_mesh"):
                algo.configure_mesh(mesh, batch_axis)
        self.state = algo.init(k0)
        if self._repl is not None:
            self.state = jax.device_put(self.state, self._repl)
        self.policy_server.push(self.state["policy"])
        self._model_cache = None
        self._model_ver = 0
        self.steps = 0

    def step(self) -> bool:
        fresh, self._model_ver = self.model_server.pull_if_newer(
            self._model_ver, sharding=self._repl)       # Pull (gated)
        if fresh is not None:
            self._model_cache = fresh
        if self._model_cache is None:
            return False
        self._key, k = jax.random.split(self._key)
        self.state, info = self.algo.improve(self.state, self._model_cache,
                                             k)
        self.steps += 1
        self.policy_server.push(self.state["policy"])   # Push
        return True
