"""Wall-clock abstraction.

The paper simulates real-robot timing: 'since data simulation is typically
much faster than real-time, the worker responsible for data collection
sleeps until the time T [200 / control-frequency] elapses' (§5.1). The
VirtualClock reproduces that deterministically: data-collection 'sleeps'
advance simulated time by the trajectory duration; model/policy workers
account their compute against the same timeline via measured host time
scaled by a speed factor.
"""
from __future__ import annotations

import threading
import time


class RealClock:
    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class VirtualClock:
    """Thread-safe simulated clock.

    ``sleep`` advances a per-thread cursor; ``now`` reports the cursor.
    Used by the benchmark harness to report 'what wall-clock time WOULD
    this have taken on the robot', matching Figure 2's methodology."""

    def __init__(self):
        self._lock = threading.Lock()
        self._cursors = {}

    def _key(self):
        return threading.get_ident()

    def now(self) -> float:
        with self._lock:
            return self._cursors.get(self._key(), 0.0)

    def sleep(self, seconds: float) -> None:
        with self._lock:
            k = self._key()
            self._cursors[k] = self._cursors.get(k, 0.0) + max(seconds, 0.0)

    def max_time(self) -> float:
        with self._lock:
            return max(self._cursors.values(), default=0.0)
