from repro.kernels.flash_attention.ops import (
    attention, decode_attention_partial, combine_partials,
)
