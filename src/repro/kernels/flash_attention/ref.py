"""Pure-jnp oracle for blocked (flash-style) attention.

Supports GQA natively (``num_q_heads`` a multiple of ``num_kv_heads``),
causal masking and an optional sliding window. The chunked variant keeps
peak memory at O(S * block_k) per head instead of O(S^2) and is what the
dry-run lowers on non-TPU backends; ``naive`` materialises the full score
matrix and is the ground-truth oracle for tests.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_idx, k_idx, causal: bool, window: int):
    """True where attention is allowed."""
    m = jnp.ones((q_idx.shape[0], k_idx.shape[0]), bool)
    if causal:
        m &= k_idx[None, :] <= q_idx[:, None]
    if window and window > 0:
        m &= k_idx[None, :] > (q_idx[:, None] - window)
    return m


def naive_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None):
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D). Returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    q_idx = jnp.arange(Sq) + (Sk - Sq)  # align ends (decode/prefill offset)
    k_idx = jnp.arange(Sk)
    m = _mask(q_idx, k_idx, causal, window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      scale: float | None = None, block_q: int = 512,
                      block_k: int = 512):
    """Online-softmax attention; same contract as :func:`naive_attention`.

    Memory-bounded reference used when the Pallas kernel is unavailable
    (CPU dry-run). Structured as scan-over-kv-blocks inside map-over-q-blocks
    so the lowered HLO stays small for long sequences.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # pad to block multiples
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qf = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))).astype(jnp.float32)
    kf = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))).astype(jnp.float32)
    vf = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))).astype(jnp.float32)
    nq, nk = qf.shape[1] // block_q, kf.shape[1] // block_k
    qf = qf.reshape(B, nq, block_q, Hkv, G, D)
    kb = kf.reshape(B, nk, block_k, Hkv, D)
    vb = vf.reshape(B, nk, block_k, Hkv, D)
    offset = Sk - Sq  # query i has absolute position i + offset

    def q_block(carry_qi):
        qi, qblk = carry_qi  # qblk: (B, block_q, Hkv, G, D)
        q_idx = qi * block_q + jnp.arange(block_q) + offset

        def kv_step(carry, kv):
            m_run, d_run, o_run = carry
            ki, kblk, vblk = kv
            k_idx = ki * block_k + jnp.arange(block_k)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk) * scale
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= k_idx[None, :] <= q_idx[:, None]
            if window and window > 0:
                mask &= k_idx[None, :] > (q_idx[:, None] - window)
            mask &= (k_idx[None, :] < Sk)  # kv padding
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            d_new = d_run * alpha + p.sum(-1)
            o_new = o_run * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk)
            return (m_new, d_new, o_new), None

        m0 = jnp.full((B, Hkv, G, block_q), NEG_INF, jnp.float32)
        d0 = jnp.zeros((B, Hkv, G, block_q), jnp.float32)
        o0 = jnp.zeros((B, Hkv, G, block_q, D), jnp.float32)
        (m, d, o), _ = jax.lax.scan(
            kv_step, (m0, d0, o0),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        o = o / jnp.maximum(d[..., None], 1e-30)
        return jnp.einsum("bhgqd->bqhgd", o)

    out = jax.lax.map(q_block, (jnp.arange(nq), jnp.moveaxis(qf, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * block_q, Hq, D)
    return out[:, :Sq].astype(q.dtype)


def decode_attention_partial(q, k_cache, v_cache, length, *, start: int = 0,
                             scale: float | None = None):
    """Single-token decode attention over a (possibly sharded) KV cache slice.

    q: (B, Hq, D); k_cache/v_cache: (B, S_loc, Hkv, D); ``length`` is the
    number of valid GLOBAL positions; ``start`` is this shard's global offset.
    Returns (o_weighted, lse) for cross-shard logsumexp combination:
      o_weighted: (B, Hq, D) = sum_j softmax-unnorm weights * v / exp(lse)
      lse:        (B, Hq)     local log-sum-exp.
    """
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32)) * scale
    pos = start + jnp.arange(S)
    s = jnp.where((pos < length)[None, None, None], s, NEG_INF)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    d = p.sum(-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    lse = m + jnp.log(jnp.maximum(d, 1e-30))
    o = o / jnp.maximum(d[..., None], 1e-30)
    return o.reshape(B, Hq, D), lse.reshape(B, Hq)


def combine_partials(outs, lses):
    """Combine per-shard (o, lse) partials: softmax-weighted merge.

    outs: (N, B, Hq, D); lses: (N, B, Hq). Used by sequence-sharded decode.
    """
    m = lses.max(0)
    w = jnp.exp(lses - m)  # (N, B, Hq)
    w = w / jnp.maximum(w.sum(0), 1e-30)
    return jnp.einsum("nbh,nbhd->bhd", w, outs)
