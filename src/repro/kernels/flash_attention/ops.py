"""Dispatching wrapper for attention.

``attention(...)`` routes to the Pallas TPU kernel when running on TPU (or
when forced via ``impl='pallas'`` with ``interpret=True`` in tests), and to
the chunked pure-jnp reference otherwise. The dry-run lowers the reference
path; its FLOPs/bytes are identical to the kernel's.
"""
from __future__ import annotations


import jax

from repro.kernels.flash_attention import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # pragma: no cover
        return False


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale: float | None = None, impl: str | None = None,
              interpret: bool = False, block_q: int = 512, block_k: int = 512):
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        from repro.kernels.flash_attention import pallas as pk
        return pk.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=interpret)
    if impl == "naive":
        return ref.naive_attention(q, k, v, causal=causal, window=window,
                                   scale=scale)
    return ref.chunked_attention(q, k, v, causal=causal, window=window,
                                 scale=scale, block_q=block_q, block_k=block_k)


decode_attention_partial = ref.decode_attention_partial
combine_partials = ref.combine_partials
