"""Pallas TPU flash attention (forward).

TPU-native adaptation (not a CUDA port): the grid's innermost dimension
iterates sequentially on a core, so the online-softmax running state
(m, l, acc) lives in VMEM scratch carried across kv-block grid steps —
no atomics, no shared-memory tiling. Block sizes default to MXU-aligned
(128 multiples). GQA is expressed in the kv BlockSpec index_map
(q head h reads kv head h // group).

Validated on CPU via interpret=True against ref.naive_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q, block_k, nk, scale, causal, window, sq, sk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (block_q, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    offset = sk - sq
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < sk
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_cur = l_scr[...] * alpha + p.sum(-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur
    l_scr[...] = l_cur
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.moveaxis(jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))), 2, 1)
    kp = jnp.moveaxis(jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))), 2, 1)
    vp = jnp.moveaxis(jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))), 2, 1)
    nq = qp.shape[2] // block_q
    nk = kp.shape[2] // block_k

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, nk=nk, scale=scale,
        causal=causal, window=window, sq=Sq, sk=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return jnp.moveaxis(out, 1, 2)[:, :Sq]
