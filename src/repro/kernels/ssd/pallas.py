"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU adaptation of state-space duality (arXiv:2405.21060): the grid walks
(batch, chunk) with the chunk axis sequential ('arbitrary' semantics), so
the inter-chunk recurrent state lives in VMEM scratch carried between grid
steps — the quadratic intra-chunk block hits the MXU, the O(1) state
update replaces the CUDA kernel's cross-block shuffle.

Layout: heads stay whole inside one kernel invocation (state (H, P, N)
fits VMEM for every assigned config). Validated with interpret=True
against ref.ssd_chunked / ref.ssd_sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
            chunk, nc, H, P, N, G):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)      # (chunk, H, P) — pre-scaled by dt
    dt = dt_ref[0, 0].astype(jnp.float32)    # (chunk, H)
    A = a_ref[...].astype(jnp.float32)    # (H,)
    Bm = b_ref[0, 0].astype(jnp.float32)     # (chunk, G, N)
    Cm = c_ref[0, 0].astype(jnp.float32)     # (chunk, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)      # (chunk, H, N)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dA = dt * A[None, :]                  # (chunk, H)
    dA_cum = jnp.cumsum(dA, axis=0)       # inclusive
    xs = x * dt[..., None]                # discretised input

    # intra-chunk (quadratic, MXU): L[i,j] = exp(dA_cum_i - dA_cum_j), i>=j
    seg = dA_cum[:, None, :] - dA_cum[None, :, :]          # (q, k, H)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where((ii >= jj)[..., None], jnp.exp(seg), 0.0)  # (q, k, H)
    CB = jnp.einsum("qhn,khn->qkh", Ch, Bh)
    y = jnp.einsum("qkh,qkh,khp->qhp", CB, L, xs)

    # inter-chunk: contribution of the carried state
    state = state_scr[...]                                  # (H, P, N)
    decay_out = jnp.exp(dA_cum)                             # (q, H)
    y += jnp.einsum("qhn,hpn,qh->qhp", Ch, state, decay_out)

    # state update for the next chunk
    chunk_decay = jnp.exp(dA_cum[-1])                       # (H,)
    decay_states = jnp.exp(dA_cum[-1][None] - dA_cum)       # (q, H)
    new_state = state * chunk_decay[:, None, None] + jnp.einsum(
        "qhn,qh,qhp->hpn", Bh, decay_states, xs)
    state_scr[...] = new_state
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_chunked(x, dt, A, B_, C, *, chunk: int = 128, initial_state=None,
                return_final_state: bool = False, interpret: bool = False):
    """Same contract as ref.ssd_chunked (no initial_state support in the
    kernel path — prefill uses the reference; decode uses the recurrence)."""
    assert initial_state is None and not return_final_state, \
        "pallas path covers the training forward; stateful prefill uses ref"
    B, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = x.shape[1]
    nc = Lp // chunk
    xr = x.reshape(B, nc, chunk, H, P)
    dtr = dt.reshape(B, nc, chunk, H)
    Br = B_.reshape(B, nc, chunk, G, N)
    Cr = C.reshape(B, nc, chunk, G, N)

    kernel = functools.partial(_kernel, chunk=chunk, nc=nc, H=H, P=P, N=N,
                               G=G)
    y = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, H, P), lambda b, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, chunk, H), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, 1, chunk, G, N), lambda b, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, chunk, G, N), lambda b, c: (b, c, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, H, P),
                               lambda b, c: (b, c, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nc, chunk, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xr, dtr, A, Br, Cr)
    return y.reshape(B, Lp, H, P)[:, :L]
