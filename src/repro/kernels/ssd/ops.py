"""Dispatching wrapper for the Mamba-2 SSD scan."""
from __future__ import annotations

import jax

from repro.kernels.ssd import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # pragma: no cover
        return False


def ssd(x, dt, A, B_, C, *, chunk: int = 128, initial_state=None,
        return_final_state: bool = False, impl: str | None = None,
        interpret: bool = False):
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        from repro.kernels.ssd import pallas as pk
        return pk.ssd_chunked(x, dt, A, B_, C, chunk=chunk,
                              initial_state=initial_state,
                              return_final_state=return_final_state,
                              interpret=interpret)
    return ref.ssd_chunked(x, dt, A, B_, C, chunk=chunk,
                           initial_state=initial_state,
                           return_final_state=return_final_state)


ssd_decode_step = ref.ssd_decode_step
ssd_sequential = ref.ssd_sequential
