"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) scan.

Implements the chunked algorithm of arXiv:2405.21060 (ssd_minimal):
quadratic attention-like computation inside fixed-size chunks (MXU-friendly)
plus a linear recurrence over chunk states. Shapes follow the paper:

  x : (B, L, H, P)   inputs per head (P = head dim)
  dt: (B, L, H)      softplus-discretised step sizes (already positive)
  A : (H,)           negative scalar decay per head
  B_: (B, L, G, N)   input projection (G groups broadcast over H)
  C : (B, L, G, N)   output projection
  returns y: (B, L, H, P) and final states (B, H, P, N)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (i>=j)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B_, C, *, chunk: int = 128, initial_state=None,
                return_final_state: bool = False):
    B, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert H % G == 0
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),) * (dt.ndim - 2))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = x.shape[1]
    nc = Lp // chunk
    f32 = jnp.float32
    xs = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(B, nc, chunk, H, P)
    dA = (dt.astype(f32) * A.astype(f32)).reshape(B, nc, chunk, H)
    Bc = B_.astype(f32).reshape(B, nc, chunk, G, N)
    Cc = C.astype(f32).reshape(B, nc, chunk, G, N)
    rep = H // G
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B, nc, Q, H, N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA_cum = jnp.cumsum(dA, axis=2)                       # (B, nc, Q, H)
    # 1. intra-chunk (diagonal blocks)
    Ltri = jnp.exp(segsum(jnp.moveaxis(dA, 2, -1)))       # (B, nc, H, Q, Q)
    y_diag = jnp.einsum("bcqhn,bckhn,bchqk,bckhp->bcqhp", Ch, Bh, Ltri, xs)
    # 2. per-chunk final states
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (B, nc, Q, H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bh, decay_states, xs)
    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # (B, nc, H)
    s0 = (jnp.zeros((B, H, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(s, inp):
        dec, st = inp  # dec: (B, H), st: (B, H, P, N)
        s_new = s * dec[..., None, None] + st
        return s_new, s

    s_final, states_prev = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    states_prev = jnp.moveaxis(states_prev, 0, 1)          # (B, nc, H, P, N)
    # 4. inter-chunk output
    state_decay_out = jnp.exp(dA_cum)                      # (B, nc, Q, H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, states_prev,
                       state_decay_out)
    y = (y_diag + y_off).reshape(B, Lp, H, P)[:, :L]
    if return_final_state:
        return y.astype(x.dtype), s_final
    return y.astype(x.dtype)


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """Single-token recurrent update.

    state: (B, H, P, N); x_t: (B, H, P); dt_t: (B, H); B_t/C_t: (B, G, N).
    Returns (y_t, new_state).
    """
    Bsz, H, P, N = state.shape
    G = B_t.shape[1]
    rep = H // G
    f32 = jnp.float32
    dA = jnp.exp(dt_t.astype(f32) * A.astype(f32))          # (B, H)
    Bh = jnp.repeat(B_t.astype(f32), rep, axis=1)            # (B, H, N)
    Ch = jnp.repeat(C_t.astype(f32), rep, axis=1)
    dBx = jnp.einsum("bh,bhp,bhn->bhpn", dt_t.astype(f32), x_t.astype(f32), Bh)
    new_state = state.astype(f32) * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x_t.dtype), new_state


def ssd_sequential(x, dt, A, B_, C, *, initial_state=None,
                   return_final_state: bool = False):
    """Token-by-token oracle (slow; ground truth for tests)."""
    B, L, H, P = x.shape
    N = B_.shape[-1]
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s, inp):
        x_t, dt_t, B_t, C_t = inp
        y, s = ssd_decode_step(s, x_t, dt_t, A, B_t, C_t)
        return s, y

    s_final, ys = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(B_, 1, 0), jnp.moveaxis(C, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)
    if return_final_state:
        return y, s_final
    return y
