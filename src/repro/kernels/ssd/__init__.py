from repro.kernels.ssd.ops import ssd, ssd_decode_step, ssd_sequential
