from repro.kernels.gmm.ops import (ensemble_mlp, ensemble_mlp_select,
                                   grouped_matmul)
