from repro.kernels.gmm.ops import ensemble_mlp, grouped_matmul
