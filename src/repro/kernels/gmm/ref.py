"""Pure-jnp oracle for grouped/batched matmul kernels.

Three entry points:
  * ``ensemble_mlp`` — K-member MLP forward on shared inputs (the MBRL
    dynamics-ensemble training loop, where every member sees every row).
  * ``grouped_matmul`` — equal-group (G, M, K) x (G, K, N) batched matmul
    (MoE capacity buffers) OR, when ``group_sizes`` is given, a RAGGED
    grouped matmul: ``lhs`` is (M, K) with rows sorted by group, row m in
    group g is multiplied by ``rhs[g]`` — M total rows of FLOPs, however
    unevenly the groups are filled.  Zero-size groups are legal.
  * ``ensemble_mlp_select`` — the sample-then-compute imagination path:
    each row is evaluated by exactly ONE assigned member (sort rows by
    member, ragged grouped MLP forward, unsort), so a batch of B rows
    costs B rows of FLOPs instead of K*B.

The ragged oracle materialises the per-row gathered ``rhs`` (M, K, N);
it is the correctness reference, not the fast path — the Pallas kernel
streams group blocks instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _group_ids(group_sizes, m):
    """Row -> group id for rows sorted by group. Rows beyond
    ``sum(group_sizes)`` (e.g. tile padding) clamp to the last group."""
    ends = jnp.cumsum(group_sizes)
    return jnp.searchsorted(ends, jnp.arange(m), side="right").clip(
        0, group_sizes.shape[0] - 1)


def grouped_matmul(lhs, rhs, group_sizes=None):
    """Equal-group: lhs (G, M, K) x rhs (G, K, N) -> (G, M, N).
    Ragged (``group_sizes`` given): lhs (M, K) sorted by group x
    rhs (G, K, N) -> (M, N), with ``group_sizes`` (G,) summing to M.
    f32 accumulation either way."""
    if group_sizes is None:
        return jax.lax.dot_general(
            lhs, rhs, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32).astype(lhs.dtype)
    gid = _group_ids(group_sizes, lhs.shape[0])
    out = jnp.einsum("mk,mkn->mn", lhs, rhs[gid],
                     preferred_element_type=jnp.float32)
    return out.astype(lhs.dtype)


def ensemble_mlp(members, x):
    """members: {"w": [ (K,a,b) ... ], "b": [ (K,b) ... ]}; x: (B, Din)
    shared across members. Returns (K, B, Dout). tanh hidden activations."""
    K = members["w"][0].shape[0]
    h = jnp.broadcast_to(x[None], (K,) + x.shape)
    n = len(members["w"])
    for i, (w, b) in enumerate(zip(members["w"], members["b"])):
        h = grouped_matmul(h, w) + b[:, None, :]
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def ensemble_mlp_select(members, x, idx, *, matmul=grouped_matmul):
    """Per-row member-assigned MLP forward (sort / compute / unsort).

    x: (B, Din); idx: (B,) int member assignment. Row b flows through
    member ``idx[b]`` only — equivalent to ``ensemble_mlp(...)[idx[b], b]``
    at 1/K the FLOPs. Implementation contract: rows are sorted by member,
    each layer is one ragged ``grouped_matmul`` over the (B, .) batch with
    ``group_sizes = bincount(idx)`` (empty members are zero-size groups),
    and the result is scattered back to input order. ``matmul`` lets the
    dispatcher swap in the Pallas ragged kernel."""
    K = members["w"][0].shape[0]
    order = jnp.argsort(idx)
    gid = idx[order]
    group_sizes = jnp.bincount(idx, length=K)
    h = x[order]
    n = len(members["w"])
    for i, (w, b) in enumerate(zip(members["w"], members["b"])):
        h = matmul(h, w, group_sizes) + b[gid]
        if i < n - 1:
            h = jnp.tanh(h)
    return jnp.zeros_like(h).at[order].set(h)
