"""Pure-jnp oracle for grouped/batched matmul kernels.

Two entry points:
  * ``ensemble_mlp`` — K-member MLP forward on shared inputs (the MBRL
    dynamics-ensemble hot loop).
  * ``grouped_matmul`` — (G, M, K) x (G, K, N) batched matmul used by the
    MoE expert FFN capacity buffers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_matmul(lhs, rhs):
    """lhs: (G, M, K); rhs: (G, K, N) -> (G, M, N), f32 accumulation."""
    return jax.lax.dot_general(
        lhs, rhs, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).astype(lhs.dtype)


def ensemble_mlp(members, x):
    """members: {"w": [ (K,a,b) ... ], "b": [ (K,b) ... ]}; x: (B, Din)
    shared across members. Returns (K, B, Dout). tanh hidden activations."""
    K = members["w"][0].shape[0]
    h = jnp.broadcast_to(x[None], (K,) + x.shape)
    n = len(members["w"])
    for i, (w, b) in enumerate(zip(members["w"], members["b"])):
        h = grouped_matmul(h, w) + b[:, None, :]
        if i < n - 1:
            h = jnp.tanh(h)
    return h
