"""Pallas TPU grouped matmul (megablox-style) for MoE expert FFNs and
the MBRL dynamics-ensemble MLP.

Grid (G, M/bm, N/bn, K/bk): the contraction axis is innermost (sequential)
with a f32 VMEM accumulator scratch; every group's (bm x bk)·(bk x bn)
tile hits the MXU. Validated with interpret=True against ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(lhs_ref, rhs_ref, out_ref, acc_scr, *, nk):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        lhs_ref[0].astype(jnp.float32), rhs_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        out_ref[0] = acc_scr[...].astype(out_ref.dtype)


def grouped_matmul(lhs, rhs, *, block_m: int = 128, block_n: int = 128,
                   block_k: int = 128, interpret: bool = False):
    """lhs: (G, M, K); rhs: (G, K, N) -> (G, M, N)."""
    G, M, K = lhs.shape
    _, _, N = rhs.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    lp = jnp.pad(lhs, ((0, 0), (0, pm), (0, pk)))
    rp = jnp.pad(rhs, ((0, 0), (0, pk), (0, pn)))
    nm, nn, nk = (M + pm) // bm, (N + pn) // bn, (K + pk) // bk

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(G, nm, nn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, M + pm, N + pn), lhs.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(lp, rp)
    return out[:, :M, :N]


def ensemble_mlp(members, x, *, interpret: bool = False):
    """Kernel-backed K-member MLP forward (same contract as ref)."""
    K = members["w"][0].shape[0]
    h = jnp.broadcast_to(x[None], (K,) + x.shape)
    n = len(members["w"])
    for i, (w, b) in enumerate(zip(members["w"], members["b"])):
        h = grouped_matmul(h, w, interpret=interpret) + b[:, None, :]
        if i < n - 1:
            h = jnp.tanh(h)
    return h
