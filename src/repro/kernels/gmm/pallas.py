"""Pallas TPU grouped matmul (megablox-style) for MoE expert FFNs and
the MBRL dynamics-ensemble MLP.

Two kernels:

* equal-group: grid (G, M/bm, N/bn, K/bk); the contraction axis is
  innermost (sequential) with a f32 VMEM accumulator scratch; every
  group's (bm x bk)·(bk x bn) tile hits the MXU.
* ragged: ``grouped_matmul(lhs (M, K), rhs (G, K, N), group_sizes)``
  with lhs rows sorted by group. Group offsets ride in via scalar
  prefetch; grid (M/bm, N/bn, G, K/bk) accumulates every group's
  contribution to an output tile in a VMEM scratch, masking the rows of
  boundary tiles a group only partially covers and skipping (``pl.when``)
  tiles a group does not touch at all — zero-size groups therefore cost
  no MXU work. FLOPs scale with M, not G*M.

Validated with interpret=True against ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(lhs_ref, rhs_ref, out_ref, acc_scr, *, nk):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        lhs_ref[0].astype(jnp.float32), rhs_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        out_ref[0] = acc_scr[...].astype(out_ref.dtype)


def _equal_grouped_matmul(lhs, rhs, *, block_m, block_n, block_k,
                          interpret):
    """lhs: (G, M, K); rhs: (G, K, N) -> (G, M, N)."""
    G, M, K = lhs.shape
    _, _, N = rhs.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    lp = jnp.pad(lhs, ((0, 0), (0, pm), (0, pk)))
    rp = jnp.pad(rhs, ((0, 0), (0, pk), (0, pn)))
    nm, nn, nk = (M + pm) // bm, (N + pn) // bn, (K + pk) // bk

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(G, nm, nn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, M + pm, N + pn), lhs.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(lp, rp)
    return out[:, :M, :N]


def _ragged_kernel(offs_ref, lhs_ref, rhs_ref, out_ref, acc_scr, *,
                   bm, ng, nk):
    i = pl.program_id(0)
    g = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when((g == 0) & (k == 0))
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    start, end = offs_ref[g], offs_ref[g + 1]
    tile_lo = i * bm

    # this group touches rows [start, end); skip tiles it doesn't reach
    @pl.when((end > tile_lo) & (start < tile_lo + bm))
    def _accum():
        rows = tile_lo + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
        mask = (rows >= start) & (rows < end)
        lhs = jnp.where(mask, lhs_ref[...].astype(jnp.float32), 0.0)
        acc_scr[...] += jax.lax.dot_general(
            lhs, rhs_ref[0].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when((g == ng - 1) & (k == nk - 1))
    def _done():
        out_ref[...] = acc_scr[...].astype(out_ref.dtype)


def _ragged_grouped_matmul(lhs, rhs, group_sizes, *, block_m, block_n,
                           block_k, interpret):
    """lhs: (M, K) rows sorted by group; rhs: (G, K, N);
    group_sizes: (G,) summing to M -> (M, N)."""
    M, K = lhs.shape
    G, _, N = rhs.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    lp = jnp.pad(lhs, ((0, pm), (0, pk)))
    rp = jnp.pad(rhs, ((0, 0), (0, pk), (0, pn)))
    nm, nn, nk = (M + pm) // bm, (N + pn) // bn, (K + pk) // bk
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(group_sizes).astype(jnp.int32)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nm, nn, G, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, g, k, offs: (i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, g, k, offs: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, g, k, offs: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_ragged_kernel, bm=bm, ng=G, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M + pm, N + pn), lhs.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(offs, lp, rp)
    return out[:M, :N]


def grouped_matmul(lhs, rhs, group_sizes=None, *, block_m: int = 128,
                   block_n: int = 128, block_k: int = 128,
                   interpret: bool = False):
    """Equal-group (lhs 3d, no sizes) or ragged (lhs 2d + group_sizes)
    grouped matmul — same contract as ``ref.grouped_matmul``."""
    if group_sizes is None:
        return _equal_grouped_matmul(lhs, rhs, block_m=block_m,
                                     block_n=block_n, block_k=block_k,
                                     interpret=interpret)
    return _ragged_grouped_matmul(lhs, rhs, group_sizes, block_m=block_m,
                                  block_n=block_n, block_k=block_k,
                                  interpret=interpret)


def ensemble_mlp(members, x, *, interpret: bool = False):
    """Kernel-backed K-member MLP forward (same contract as ref)."""
    K = members["w"][0].shape[0]
    h = jnp.broadcast_to(x[None], (K,) + x.shape)
    n = len(members["w"])
    for i, (w, b) in enumerate(zip(members["w"], members["b"])):
        h = grouped_matmul(h, w, interpret=interpret) + b[:, None, :]
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def ensemble_mlp_select(members, x, idx, *, interpret: bool = False):
    """Kernel-backed sort/compute/unsort member-assigned forward (same
    contract as ``ref.ensemble_mlp_select``): B rows of MXU work, not K*B."""
    from repro.kernels.gmm import ref as _ref
    return _ref.ensemble_mlp_select(
        members, x, idx,
        matmul=functools.partial(grouped_matmul, interpret=interpret))
