"""Dispatching wrapper for grouped matmul / ensemble MLP."""
from __future__ import annotations

import jax

from repro.kernels.gmm import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # pragma: no cover
        return False


def grouped_matmul(lhs, rhs, *, impl: str | None = None,
                   interpret: bool = False):
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        from repro.kernels.gmm import pallas as pk
        return pk.grouped_matmul(lhs, rhs, interpret=interpret)
    return ref.grouped_matmul(lhs, rhs)


def ensemble_mlp(members, x, *, impl: str | None = None,
                 interpret: bool = False):
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        from repro.kernels.gmm import pallas as pk
        return pk.ensemble_mlp(members, x, interpret=interpret)
    return ref.ensemble_mlp(members, x)
