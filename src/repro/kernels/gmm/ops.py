"""Dispatching wrapper for grouped matmul / ensemble MLP.

``grouped_matmul`` covers both layouts: equal-group batched (lhs 3d) and
ragged (lhs 2d + ``group_sizes``, rows sorted by group — MegaBlocks-style
sample-then-compute).  ``ensemble_mlp_select`` is the per-row
member-assigned forward built on the ragged layout; its ``impl``:

* ``pallas`` — sort rows by member, ragged Pallas kernel, unsort.
  B rows of MXU FLOPs regardless of K. Default on TPU.
* ``ref``    — same sort/compute/unsort contract on the pure-jnp ragged
  oracle (gathers per-row weights). The parity baseline.
* ``dense``  — evaluate ALL K members and select (K*B FLOPs). Small
  ensembles on small hosts (CPU imagination, K<=5, hidden<=128) are
  latency- not FLOP-bound, and one batched matmul beats per-row weight
  gathers there — measured in benchmarks/hotpath.py. Default on CPU
  only; GPU defaults to ``ref`` (FLOP-bound at real sizes, and the
  gathered batched matmul keeps the no-K*-overcompute invariant).
"""
from __future__ import annotations

import jax

from repro.kernels.gmm import ref


def _backend() -> str:
    try:
        return jax.default_backend()
    except RuntimeError:  # pragma: no cover
        return "cpu"


def _on_tpu() -> bool:
    return _backend() == "tpu"


def grouped_matmul(lhs, rhs, group_sizes=None, *, impl: str | None = None,
                   interpret: bool = False):
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        from repro.kernels.gmm import pallas as pk
        return pk.grouped_matmul(lhs, rhs, group_sizes, interpret=interpret)
    return ref.grouped_matmul(lhs, rhs, group_sizes)


def ensemble_mlp(members, x, *, impl: str | None = None,
                 interpret: bool = False):
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        from repro.kernels.gmm import pallas as pk
        return pk.ensemble_mlp(members, x, interpret=interpret)
    return ref.ensemble_mlp(members, x)


def ensemble_mlp_select(members, x, idx, *, impl: str | None = None,
                        interpret: bool = False):
    """Forward row b through member ``idx[b]`` only. Same output as
    ``ensemble_mlp(members, x)[idx[b], b]`` for every b."""
    if impl is None:
        backend = _backend()
        impl = ("pallas" if backend == "tpu"
                else "dense" if backend == "cpu" else "ref")
    if impl == "pallas":
        from repro.kernels.gmm import pallas as pk
        return pk.ensemble_mlp_select(members, x, idx, interpret=interpret)
    if impl == "ref":
        return ref.ensemble_mlp_select(members, x, idx)
    preds = ref.ensemble_mlp(members, x)            # (K, B, D)
    return jax.numpy.take_along_axis(
        preds, idx[None, :, None], axis=0)[0]
