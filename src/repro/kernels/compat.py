"""jax version compat shared by the pallas TPU kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# naming compat: CompilerParams (new) vs TPUCompilerParams (older jax)
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; incompatible jax version")
