"""Pallas TPU megakernel for the fused imagination step (ISSUE 10).

One ``pallas_call`` per horizon step: for each batch row-block the
kernel runs the policy MLP head, forms the pre-tanh/tanh actions from
pre-drawn noise, normalises the dynamics input into a VMEM scratch, and
then sweeps the ensemble members sequentially — each member's whole MLP
forward runs on the row-block with every intermediate activation held in
VMEM (nothing spills to HBM between layers), and only the rows assigned
to that member are accumulated into the output.

Layout follows the ragged ``gmm`` kernel: rows arrive PRE-SORTED by
member, cumulative group offsets ride in via scalar prefetch
(``PrefetchScalarGridSpec``), boundary tiles a member only partially
covers are row-masked with a ``broadcasted_iota`` compare, and tiles a
member does not touch at all are skipped with ``pl.when`` — zero-size
groups (members no row sampled) cost no MXU work.

Grid: ``(B/bm, K)`` with the member dimension innermost and
``arbitrary`` (sequential), so the per-block scratches written at
``g == 0`` (normalised input, zeroed accumulator) stay live across the
member sweep and the next state is emitted at ``g == K - 1``.

Validated with ``interpret=True`` against ``ref`` (the pure-jnp oracle);
on real TPUs the tiny MBRL feature dims (obs+act < 8) would be padded to
the (8, 128) f32 tile by Mosaic — see docs/KERNELS.md for the bring-up
checklist.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _fused_kernel(offs_ref, s_ref, eps_ref, *refs, bm, n_groups, n_dyn,
                  n_pol):
    dyn_w = refs[:n_dyn]
    dyn_b = refs[n_dyn:2 * n_dyn]
    pol_w = refs[2 * n_dyn:2 * n_dyn + n_pol]
    pol_b = refs[2 * n_dyn + n_pol:2 * n_dyn + 2 * n_pol]
    (log_std_ref, mu_in_ref, sig_in_ref, mu_out_ref, sig_out_ref,
     s2_ref, a_ref, pre_ref, xn_scr, acc_scr) = refs[2 * (n_dyn + n_pol):]

    i = pl.program_id(0)
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _policy_head():
        # policy MLP + reparameterised sample, all in VMEM
        h = s_ref[...].astype(jnp.float32)
        for li, (w, b) in enumerate(zip(pol_w, pol_b)):
            h = jax.lax.dot_general(
                h, w[...].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32) + b[...]
            if li < n_pol - 1:
                h = jnp.tanh(h)
        pre = h + jnp.exp(log_std_ref[...].astype(jnp.float32)) \
            * eps_ref[...].astype(jnp.float32)
        a = jnp.tanh(pre)
        pre_ref[...] = pre.astype(pre_ref.dtype)
        a_ref[...] = a.astype(a_ref.dtype)
        x = jnp.concatenate([s_ref[...].astype(jnp.float32), a], axis=1)
        xn_scr[...] = (x - mu_in_ref[...]) / sig_in_ref[...]
        acc_scr[...] = jnp.zeros_like(acc_scr)

    start, end = offs_ref[g], offs_ref[g + 1]
    tile_lo = i * bm

    # member g owns sorted rows [start, end); skip blocks it doesn't touch
    @pl.when((end > tile_lo) & (start < tile_lo + bm))
    def _member_mlp():
        h = xn_scr[...]
        for li, (w, b) in enumerate(zip(dyn_w, dyn_b)):
            h = jax.lax.dot_general(
                h, w[0].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32) + b[0]
            if li < n_dyn - 1:
                h = jnp.tanh(h)
        rows = tile_lo + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
        mask = (rows >= start) & (rows < end)
        acc_scr[...] += jnp.where(mask, h, 0.0)

    @pl.when(g == n_groups - 1)
    def _emit_next_state():
        s2 = s_ref[...].astype(jnp.float32) \
            + acc_scr[...] * sig_out_ref[...] + mu_out_ref[...]
        s2_ref[...] = s2.astype(s2_ref.dtype)


def fused_step_sorted(members, norm, pol, s, eps, offsets, *,
                      block_b: int = 128, interpret: bool = False):
    """Fused step on rows PRE-SORTED by member.

    s: (B, obs); eps: (B, act); offsets: (K+1,) int32 cumulative group
    offsets (``offsets[g]..offsets[g+1]`` are member g's rows). Returns
    ``(s2, a, pre)`` in the same sorted order; the dispatcher owns the
    sort/unsort (hoisted out of the rollout scan).
    """
    B, obs_dim = s.shape
    act_dim = eps.shape[1]
    K = members["w"][0].shape[0]
    n_dyn, n_pol = len(members["w"]), len(pol["w"])
    bm = min(block_b, B)
    pm = (-B) % bm
    nm = (B + pm) // bm
    sp = jnp.pad(s, ((0, pm), (0, 0)))
    ep = jnp.pad(eps, ((0, pm), (0, 0)))

    # 1-D params ride in as (1, dim) blocks (TPU refs want >= 2-D)
    row = lambda v: v.reshape(1, -1)
    operands = (
        [sp, ep]
        + list(members["w"])                       # (K, din, dout) each
        + [b.reshape(K, 1, -1) for b in members["b"]]
        + list(pol["w"])                           # (din, dout) each
        + [row(b) for b in pol["b"]]
        + [row(pol["log_std"]), row(norm["mu_in"]), row(norm["sig_in"]),
           row(norm["mu_out"]), row(norm["sig_out"])]
    )

    def fixed(shape):        # whole-array block, same for every (i, g)
        nd = len(shape)
        return pl.BlockSpec(shape, lambda i, g, offs, _n=nd: (0,) * _n)

    def member_block(shape):  # (1, ·, ·) slice of a (K, ·, ·) stack at g
        return pl.BlockSpec((1,) + shape[1:],
                            lambda i, g, offs: (g,) + (0,) * (len(shape) - 1))

    in_specs = (
        [pl.BlockSpec((bm, obs_dim), lambda i, g, offs: (i, 0)),
         pl.BlockSpec((bm, act_dim), lambda i, g, offs: (i, 0))]
        + [member_block(w.shape) for w in members["w"]]
        + [member_block((K, 1, b.shape[-1])) for b in members["b"]]
        + [fixed(w.shape) for w in pol["w"]]
        + [fixed((1, b.shape[-1])) for b in pol["b"]]
        + [fixed((1, act_dim)), fixed((1, obs_dim + act_dim)),
           fixed((1, obs_dim + act_dim)), fixed((1, obs_dim)),
           fixed((1, obs_dim))]
    )
    out_specs = (
        pl.BlockSpec((bm, obs_dim), lambda i, g, offs: (i, 0)),
        pl.BlockSpec((bm, act_dim), lambda i, g, offs: (i, 0)),
        pl.BlockSpec((bm, act_dim), lambda i, g, offs: (i, 0)),
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nm, K),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((bm, obs_dim + act_dim), jnp.float32),
                        pltpu.VMEM((bm, obs_dim), jnp.float32)],
    )
    s2, a, pre = pl.pallas_call(
        functools.partial(_fused_kernel, bm=bm, n_groups=K, n_dyn=n_dyn,
                          n_pol=n_pol),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((B + pm, obs_dim), s.dtype),
                   jax.ShapeDtypeStruct((B + pm, act_dim), s.dtype),
                   jax.ShapeDtypeStruct((B + pm, act_dim), s.dtype)),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(offsets.astype(jnp.int32), *operands)
    return s2[:B], a[:B], pre[:B]
