# Fused imagination-step kernel family (ISSUE 10): one pass per horizon
# step — policy head + assigned-member dynamics MLP for a whole batch
# row-block, intermediates kept in VMEM. Same tier shape as the
# siblings: ref.py (pure-jnp oracle, the bit-reference), pallas.py (TPU
# megakernel, validated with interpret=True), ops.py (backend dispatch +
# the XLA-fused jnp fallback that carries the CPU speedup).
