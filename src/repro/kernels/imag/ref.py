"""Pure-jnp oracle for the fused imagination step.

One imagination step of the Dyna loop, as a single function of
pre-drawn randomness::

    mu   = policy_mlp(pol, s)                    # tanh-MLP mean
    pre  = mu + exp(pol.log_std) * eps           # pre-tanh action
    a    = tanh(pre)
    xn   = (concat(s, a) - mu_in) / sig_in       # dynamics input norm
    dyn  = member_mlp[member_idx[b]](xn[b])      # per-row assigned member
    s2   = s + dyn * sig_out + mu_out

``eps`` is standard-normal noise drawn OUTSIDE the step (the rollout
hoists the whole horizon's draws; ``jax.vmap``-ing ``normal`` over
pre-split keys reproduces the per-step draws bit-for-bit), and
``member_idx`` is the uniform-prior member assignment from
``mbrl.dynamics.sample_members``.

This oracle is the bit-reference for the family: it spells the member
selection exactly like the CPU ``dense`` path of ``kernels/gmm`` —
evaluate all K members with the shared-input ``ensemble_mlp`` and
``take_along_axis`` the assigned rows — so under the same assignment it
is bit-identical to the legacy two-call step
(``policy.sample_action`` + ``dynamics.predict_assigned`` on CPU). The
Pallas megakernel and the flat XLA fallback in ``ops.py`` agree with it
to float tolerance.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.gmm import ref as gmm_ref


def policy_mu(pol, s):
    """Mean head of the tanh-squashed Gaussian policy — the same MLP
    arithmetic as ``mbrl.policy.mean_action`` (tanh hidden, linear out),
    kept local so the kernel tier never imports ``mbrl``."""
    h = s
    n = len(pol["w"])
    for i, (w, b) in enumerate(zip(pol["w"], pol["b"])):
        h = h @ w + b
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def fused_step(members, norm, pol, s, eps, member_idx):
    """One fused imagination step on a batch.

    members: ``{"w": [(K, a, b), ...], "b": [(K, b), ...]}`` dynamics
    ensemble; norm: ``mu_in/sig_in/mu_out/sig_out`` dict; pol: policy
    params (``w``/``b``/``log_std``); s: (B, obs); eps: (B, act) standard
    normal; member_idx: (B,) int in [0, K).

    Returns ``(s2, a, pre)``: next states, tanh actions, pre-tanh
    actions — everything the rollout scans need.
    """
    mu = policy_mu(pol, s)
    pre = mu + jnp.exp(pol["log_std"]) * eps
    a = jnp.tanh(pre)
    x = jnp.concatenate([s, a], -1)
    xn = (x - norm["mu_in"]) / norm["sig_in"]
    dyn_all = gmm_ref.ensemble_mlp(members, xn)          # (K, B, obs)
    dyn = jnp.take_along_axis(dyn_all, member_idx[None, :, None],
                              axis=0)[0]
    s2 = s + dyn * norm["sig_out"] + norm["mu_out"]
    return s2, a, pre
