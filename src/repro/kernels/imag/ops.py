"""Dispatching wrapper for the fused imagination step.

``fused_step(members, norm, pol, s, eps, member_idx)`` runs one whole
imagination step — policy head, reparameterised action sample, assigned-
member dynamics forward, denormalised next state — as a single
dispatchable unit. ``impl``:

* ``pallas`` — sort rows by member, one Pallas megakernel over the
  row-blocks (policy + member MLPs fused in VMEM, scalar-prefetch group
  offsets, masked boundary tiles, zero-size-group skip), unsort. B rows
  of MXU FLOPs regardless of K. Default on TPU. Differentiable: a
  ``custom_vjp`` backs the kernel with the jnp reference's VJP, so
  MB-MPO's gradients THROUGH the rollout keep working.
* ``fused`` — the XLA-fused flat spelling: the policy head feeds
  straight into one flattened ``(B, din) @ (din, K*dout)`` matmul per
  dynamics layer with a per-layer member gather. K*B FLOPs, but tiny
  MBRL ensembles on CPU are launch- not FLOP-bound (the same trade as
  ``kernels/gmm``'s ``dense`` select), and collapsing the per-step
  sort / ragged matmul / unsort / policy round-trips into this one
  straight-line body is what cuts the CPU rollout latency (measured in
  ``benchmarks/hotpath.py`` as ``imagine_fused_speedup_x``). Default on
  CPU.
* ``ref`` — the pure-jnp oracle (dense compute-all + select), the
  bit-reference for both.

``sort_plan`` precomputes the pallas impl's sort/unsort plan; the
rollout calls it ONCE for the whole horizon's member draws so no
argsort/bincount runs inside the scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.imag import ref


def _backend() -> str:
    try:
        return jax.default_backend()
    except RuntimeError:  # pragma: no cover
        return "cpu"


def default_impl() -> str:
    """Backend-chosen impl: the megakernel on TPU, the XLA-fused flat
    spelling elsewhere (CPU/GPU have no Mosaic lowering)."""
    return "pallas" if _backend() == "tpu" else "fused"


def sort_plan(member_idx, n_groups: int):
    """Sort/unsort plan for the pallas impl: ``(order, offsets)``.

    member_idx: (..., B) int — leading axes (e.g. the horizon) are
    planned in one call, so the rollout scan carries precomputed plans
    instead of re-sorting every step. ``order`` sorts the trailing axis
    by member; ``offsets`` (..., K+1) are cumulative group offsets.
    """
    order = jnp.argsort(member_idx, axis=-1)
    sizes = (member_idx[..., :, None]
             == jnp.arange(n_groups)).sum(axis=-2)
    zeros = jnp.zeros(sizes.shape[:-1] + (1,), jnp.int32)
    offsets = jnp.concatenate(
        [zeros, jnp.cumsum(sizes, axis=-1).astype(jnp.int32)], axis=-1)
    return order, offsets


def _fused_flat(members, norm, pol, s, eps, member_idx):
    """XLA fallback: one flattened matmul + member gather per layer."""
    mu = ref.policy_mu(pol, s)
    pre = mu + jnp.exp(pol["log_std"]) * eps
    a = jnp.tanh(pre)
    x = jnp.concatenate([s, a], -1)
    h = (x - norm["mu_in"]) / norm["sig_in"]
    K = members["w"][0].shape[0]
    col = member_idx[:, None, None]
    n = len(members["w"])
    for i, (w, b) in enumerate(zip(members["w"], members["b"])):
        din, dout = w.shape[1], w.shape[2]
        hk = (h @ w.transpose(1, 0, 2).reshape(din, K * dout)
              ).reshape(h.shape[0], K, dout)
        h = jnp.take_along_axis(hk, col, axis=1)[:, 0] + b[member_idx]
        if i < n - 1:
            h = jnp.tanh(h)
    s2 = s + h * norm["sig_out"] + norm["mu_out"]
    return s2, a, pre


# ---------------------------------------------------------------- pallas
# The kernel has no autodiff rule; MB-MPO differentiates THROUGH the
# rollout, so the pallas impl carries a custom_vjp whose backward pass is
# the VJP of the jnp reference on the same (sorted) rows. ``gid`` is the
# sorted member id per row (int: its cotangent is None).

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _pallas_sorted(interpret, block_b, offsets, gid, members, norm, pol,
                   s, eps):
    from repro.kernels.imag import pallas as pk
    return pk.fused_step_sorted(members, norm, pol, s, eps, offsets,
                                block_b=block_b, interpret=interpret)


def _pallas_sorted_fwd(interpret, block_b, offsets, gid, members, norm,
                       pol, s, eps):
    out = _pallas_sorted(interpret, block_b, offsets, gid, members, norm,
                         pol, s, eps)
    return out, (gid, members, norm, pol, s, eps)


def _pallas_sorted_bwd(interpret, block_b, res, ct):
    gid, members, norm, pol, s, eps = res
    _, vjp = jax.vjp(
        lambda m, n, p, s_, e_: ref.fused_step(m, n, p, s_, e_, gid),
        members, norm, pol, s, eps)
    d_members, d_norm, d_pol, d_s, d_eps = vjp(ct)
    return None, None, d_members, d_norm, d_pol, d_s, d_eps


_pallas_sorted.defvjp(_pallas_sorted_fwd, _pallas_sorted_bwd)


def fused_step(members, norm, pol, s, eps, member_idx, *,
               impl: str | None = None, interpret: bool = False,
               plan=None, block_b: int = 128):
    """One fused imagination step; see module docstring for impls.

    ``plan``: optional precomputed ``sort_plan`` output for this step
    (pallas impl only — ``fused``/``ref`` are row-order-blind and ignore
    it). Returns ``(s2, a, pre)`` in input row order."""
    if impl is None:
        impl = default_impl()
    if impl == "pallas":
        if plan is None:
            plan = sort_plan(member_idx, members["w"][0].shape[0])
        order, offsets = plan
        out = _pallas_sorted(interpret, block_b, offsets,
                             member_idx[order], members, norm, pol,
                             s[order], eps[order])
        unsort = lambda v: jnp.zeros_like(v).at[order].set(v)
        return tuple(unsort(v) for v in out)
    if impl == "fused":
        return _fused_flat(members, norm, pol, s, eps, member_idx)
    return ref.fused_step(members, norm, pol, s, eps, member_idx)
