"""Phi-3-vision 4.2B — VLM: phi3-mini decoder + CLIP frontend (stubbed).
[hf:microsoft/Phi-3-vision-128k-instruct]

The vision tower is a stub per the assignment: input_specs supplies
precomputed patch embeddings occupying the first seq_len//8 positions."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm", num_layers=32, d_model=3072,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32064,
    modality="vision", rope_theta=10_000.0,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

REDUCED = ModelConfig(
    name="phi-3-vision-reduced", family="vlm", num_layers=2, d_model=256,
    num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512,
    modality="vision", source="hf:microsoft/Phi-3-vision-128k-instruct",
)
