"""SeamlessM4T-medium — encoder-decoder speech/text backbone.
[arXiv:2308.11596]

Audio frontend (mel + conformer conv) is stubbed: input_specs supplies
precomputed frame embeddings (B, S, d). 12 encoder + 12 decoder layers,
classic (non-gated) GELU FFN."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", num_layers=12,
    encoder_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, modality="audio", mlp_type="gelu",
    source="arXiv:2308.11596",
)

REDUCED = ModelConfig(
    name="seamless-reduced", family="encdec", num_layers=2,
    encoder_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
    vocab_size=512, modality="audio", mlp_type="gelu",
    source="arXiv:2308.11596",
)
