"""Qwen3-14B — dense decoder, GQA kv=8, qk-norm. [hf:Qwen/Qwen3-8B family]

Note: 40 q-heads are padded to 48 under tp=16 (zero-output pad heads; see
DESIGN.md hardware-adaptation notes)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=17408,
    vocab_size=151936, qk_norm=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)

REDUCED = ModelConfig(
    name="qwen3-14b-reduced", family="dense", num_layers=2, d_model=256,
    num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
    qk_norm=True, rope_theta=1_000_000.0, source="hf:Qwen/Qwen3-8B",
)
