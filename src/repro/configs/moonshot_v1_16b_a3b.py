"""Moonlight-16B-A3B (moonshot) — MoE 64 experts top-6, GQA kv=16.
[hf:moonshotai/Moonlight-16B-A3B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", num_layers=48, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1408,
    vocab_size=163840, num_experts=64, top_k=6, rope_theta=50_000.0,
    source="hf:moonshotai/Moonlight-16B-A3B",
)

REDUCED = ModelConfig(
    name="moonshot-reduced", family="moe", num_layers=2, d_model=256,
    num_heads=4, num_kv_heads=4, head_dim=64, d_ff=128, vocab_size=512,
    num_experts=4, top_k=2, source="hf:moonshotai/Moonlight-16B-A3B",
    capacity_factor=8.0,
)
