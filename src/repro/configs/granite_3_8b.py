"""Granite-3 8B — dense decoder, GQA kv=8. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense", num_layers=40, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=12800, vocab_size=49155,
    rope_theta=10_000.0, source="hf:ibm-granite/granite-3.0-2b-base",
)

REDUCED = ModelConfig(
    name="granite-3-8b-reduced", family="dense", num_layers=2, d_model=256,
    num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
    source="hf:ibm-granite/granite-3.0-2b-base",
)
