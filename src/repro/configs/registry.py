"""Architecture registry: --arch <id> resolves here.

Each assigned architecture module defines ``CONFIG`` (the exact published
shape, cited in ``source``) and ``REDUCED`` (a tiny same-family variant for
CPU smoke tests: <=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = [
    "glm4_9b",
    "phi3_vision_4_2b",
    "qwen3_moe_235b_a22b",
    "mixtral_8x7b",
    "qwen3_14b",
    "seamless_m4t_medium",
    "granite_3_8b",
    "zamba2_7b",
    "moonshot_v1_16b_a3b",
    "mamba2_2_7b",
]

# CLI ids (dashes) -> module names
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
ALIASES.update({
    "glm4-9b": "glm4_9b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-14b": "qwen3_14b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "granite-3-8b": "granite_3_8b",
    "zamba2-7b": "zamba2_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mamba2-2.7b": "mamba2_2_7b",
})

# archs whose expert weights are FSDP-stored over the data axis
FSDP_ARCHS = {"qwen3_moe_235b_a22b", "mixtral_8x7b", "moonshot_v1_16b_a3b"}

# per-arch microbatch token target (MoE dispatch buffers want smaller)
MICROBATCH_TOKENS = {"qwen3_moe_235b_a22b": 4096, "mixtral_8x7b": 4096,
                     "moonshot_v1_16b_a3b": 4096}

# long_500k applicability: "native" (sub-quadratic as-published), "window"
# (run with the documented sliding-window variant), or "skip"
LONG_CONTEXT = {
    "mamba2_2_7b": "native",
    "zamba2_7b": "native",
    "mixtral_8x7b": "native",        # SWA is part of the arch
    "glm4_9b": "window",
    "qwen3_14b": "window",
    "granite_3_8b": "window",
    "phi3_vision_4_2b": "window",
    "qwen3_moe_235b_a22b": "window",
    "moonshot_v1_16b_a3b": "window",
    "seamless_m4t_medium": "skip",   # enc-dec speech model; see DESIGN.md
}

LONG_WINDOW = 4096


def normalize(arch_id: str) -> str:
    key = arch_id.replace("_", "-").lower()
    if key in ALIASES:
        return ALIASES[key]
    if arch_id in ARCH_IDS:
        return arch_id
    raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ALIASES)}")


def get_config(arch_id: str, *, reduced: bool = False,
               long_context: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(arch_id)}")
    cfg = mod.REDUCED if reduced else mod.CONFIG
    if long_context and not reduced:
        mode = LONG_CONTEXT[normalize(arch_id)]
        if mode == "skip":
            raise ValueError(f"{arch_id}: long_500k not applicable")
        if mode == "window" and not cfg.attn_window:
            import dataclasses
            cfg = dataclasses.replace(cfg, attn_window=LONG_WINDOW,
                                      name=cfg.name + "+swa")
    return cfg


def all_configs(reduced: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, reduced=reduced) for a in ARCH_IDS}
