"""GLM-4-9B — dense decoder, GQA kv=2, RoPE. [hf:THUDM/glm-4-9b]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense", num_layers=40, d_model=4096,
    num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=151552,
    rope_theta=10_000.0, source="hf:THUDM/glm-4-9b",
)

REDUCED = ModelConfig(
    name="glm4-9b-reduced", family="dense", num_layers=2, d_model=256,
    num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
    rope_theta=10_000.0, source="hf:THUDM/glm-4-9b",
)
