"""Mixtral-8x7B — MoE 8 experts top-2, GQA kv=8, sliding-window attention.
[arXiv:2401.04088]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=32000,
    num_experts=8, top_k=2, attn_window=4096, rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)

REDUCED = ModelConfig(
    name="mixtral-reduced", family="moe", num_layers=2, d_model=256,
    num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
    num_experts=4, top_k=2, attn_window=64, source="arXiv:2401.04088",
    capacity_factor=8.0,
)
