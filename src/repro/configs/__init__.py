from repro.configs.registry import (ARCH_IDS, ALIASES, FSDP_ARCHS,
                                    LONG_CONTEXT, get_config, normalize,
                                    all_configs)
