"""Qwen3-235B-A22B — MoE, 128 experts top-8, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B scaled per assignment]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe", num_layers=94, d_model=4096,
    num_heads=64, num_kv_heads=4, head_dim=128, d_ff=1536,
    vocab_size=151936, num_experts=128, top_k=8, qk_norm=True,
    rope_theta=1_000_000.0, source="hf:Qwen/Qwen3-30B-A3B",
)

REDUCED = ModelConfig(
    name="qwen3-moe-reduced", family="moe", num_layers=2, d_model=256,
    num_heads=4, num_kv_heads=2, head_dim=64, d_ff=128, vocab_size=512,
    num_experts=4, top_k=2, qk_norm=True, source="hf:Qwen/Qwen3-30B-A3B",
    capacity_factor=8.0,
)
