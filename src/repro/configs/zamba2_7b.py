"""Zamba2-7B — hybrid: Mamba2 backbone + shared attention block every 6
layers (weights shared across invocations). [arXiv:2411.15242]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
    num_heads=32, num_kv_heads=32, d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    attn_every=6, source="arXiv:2411.15242",
)

REDUCED = ModelConfig(
    name="zamba2-reduced", family="hybrid", num_layers=3, d_model=256,
    num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_groups=1,
    attn_every=2, source="arXiv:2411.15242",
)
