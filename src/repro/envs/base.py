"""Pure-JAX continuous-control environments.

Each env is a stateless dataclass with::

  reset(key)            -> state  (obs == state here; both jnp arrays)
  step(state, action)   -> (next_state, reward)
  obs_dim / act_dim / horizon / dt (control period, seconds)

Being pure jnp, envs jit/vmap — the data-collection worker is itself a JAX
program (see DESIGN.md hardware-adaptation notes). ``dt`` drives the
paper's wall-clock simulation: collecting one trajectory "takes"
horizon * dt seconds of robot time (§5.1).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Env:
    obs_dim: int
    act_dim: int
    horizon: int
    dt: float  # control period in seconds (1/control frequency)
    name: str = "env"

    def reset(self, key):
        raise NotImplementedError

    def step(self, state, action):
        raise NotImplementedError

    def reward(self, s, a, s2):
        """Reward as a function of (s, a, s') — used by imagination."""
        raise NotImplementedError

    def reset_batch(self, key, n: int):
        return jax.vmap(self.reset)(jax.random.split(key, n))

    # ------------------------------------------------------------------
    def rollout(self, key, policy_fn, policy_params, *, horizon=None):
        """Collect one trajectory with a policy. Returns dict of stacked
        (obs, act, next_obs, reward)."""
        H = horizon or self.horizon
        k0, key = jax.random.split(key)
        s0 = self.reset(k0)

        def step_fn(carry, k):
            s = carry
            a = policy_fn(policy_params, s, k)
            s2, r = self.step(s, a)
            return s2, (s, a, s2, r)

        _, (obs, act, nobs, rew) = jax.lax.scan(
            step_fn, s0, jax.random.split(key, H))
        return {"obs": obs, "act": act, "next_obs": nobs, "rew": rew}


def angle_normalize(x):
    return ((x + jnp.pi) % (2 * jnp.pi)) - jnp.pi
