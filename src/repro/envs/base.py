"""Pure-JAX continuous-control environments.

Each env is a stateless dataclass with::

  reset(key)            -> state  (obs == state here; both jnp arrays)
  step(state, action)   -> (next_state, reward)
  obs_dim / act_dim / horizon / dt (control period, seconds)

Being pure jnp, envs jit/vmap — the data-collection worker is itself a JAX
program (see DESIGN.md hardware-adaptation notes). ``dt`` drives the
paper's wall-clock simulation: collecting one trajectory "takes"
horizon * dt seconds of robot time (§5.1).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def lane_keys(key, n: int):
    """Per-lane RNG streams for a batched rollout (the env farm,
    ISSUE 6): lane 0 keeps ``key`` UNTOUCHED — the same derivation rule
    as ``workers.collector_key`` — and every other lane folds its index
    in. A farm of one therefore consumes exactly the single-rollout
    stream, and distinct lanes draw independent streams."""
    if n == 1:
        return key[None]
    return jnp.stack([key] + [jax.random.fold_in(key, i)
                              for i in range(1, n)])


@dataclasses.dataclass(frozen=True)
class Env:
    obs_dim: int
    act_dim: int
    horizon: int
    dt: float  # control period in seconds (1/control frequency)
    name: str = "env"

    def reset(self, key):
        raise NotImplementedError

    def step(self, state, action):
        raise NotImplementedError

    def reward(self, s, a, s2):
        """Reward as a function of (s, a, s') — used by imagination."""
        raise NotImplementedError

    def reset_batch(self, key, n: int):
        return jax.vmap(self.reset)(jax.random.split(key, n))

    # ------------------------------------------------------------------
    def rollout(self, key, policy_fn, policy_params, *, horizon=None):
        """Collect one trajectory with a policy. Returns dict of stacked
        (obs, act, next_obs, reward)."""
        H = horizon or self.horizon
        k0, key = jax.random.split(key)
        s0 = self.reset(k0)

        def step_fn(carry, k):
            s = carry
            a = policy_fn(policy_params, s, k)
            s2, r = self.step(s, a)
            return s2, (s, a, s2, r)

        _, (obs, act, nobs, rew) = jax.lax.scan(
            step_fn, s0, jax.random.split(key, H))
        return {"obs": obs, "act": act, "next_obs": nobs, "rew": rew}

    def rollout_batch(self, key, policy_fn, policy_params, n: int, *,
                      horizon=None):
        """Collect ``n`` trajectories at once — the env farm (ISSUE 6):
        one vmapped scan simulates n robots on one device, so a
        collector's per-step cost grows far slower than n. Returns the
        same dict as :meth:`rollout` with a leading batch axis
        ``(n, H, ...)``.

        Lane streams come from :func:`lane_keys` (lane 0 keeps ``key``).
        ``n == 1`` DELEGATES to :meth:`rollout` instead of vmapping, so a
        one-robot farm is the single-rollout program bit for bit —
        vmapped arithmetic is not guaranteed bitwise-equal to its scalar
        counterpart, and the B=1 identity invariant matters more than
        uniformity here."""
        n = int(n)
        if n < 1:
            raise ValueError(f"rollout_batch needs n >= 1, got {n}")
        if n == 1:
            traj = self.rollout(key, policy_fn, policy_params,
                                horizon=horizon)
            return jax.tree.map(lambda x: x[None], traj)
        return jax.vmap(
            lambda k: self.rollout(k, policy_fn, policy_params,
                                   horizon=horizon))(lane_keys(key, n))


def angle_normalize(x):
    return ((x + jnp.pi) % (2 * jnp.pi)) - jnp.pi
