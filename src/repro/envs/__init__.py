from repro.envs.arm import Arm7, Reacher2, make_env
from repro.envs.base import Env
from repro.envs.classic import CartpoleSwingup, Pendulum, SpringHopper
