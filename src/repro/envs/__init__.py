from repro.envs.arm import Arm7, Reacher2, make_env
from repro.envs.base import Env, lane_keys
from repro.envs.classic import CartpoleSwingup, Pendulum, SpringHopper
