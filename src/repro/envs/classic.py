"""Pendulum swing-up, cart-pole swing-up and a planar hopper-like
benchmark, all as analytic jnp dynamics (MuJoCo is unavailable offline —
see DESIGN.md assumption table)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.envs.base import Env, angle_normalize


@dataclasses.dataclass(frozen=True)
class Pendulum(Env):
    obs_dim: int = 3
    act_dim: int = 1
    horizon: int = 200
    dt: float = 0.05
    name: str = "pendulum"
    g: float = 10.0
    m: float = 1.0
    l: float = 1.0
    max_torque: float = 2.0
    max_speed: float = 8.0

    def reset(self, key):
        th = jax.random.uniform(key, (), minval=-jnp.pi, maxval=jnp.pi)
        thdot = jax.random.uniform(jax.random.fold_in(key, 1), (),
                                   minval=-1.0, maxval=1.0)
        return jnp.array([jnp.cos(th), jnp.sin(th), thdot])

    def reward(self, s, a, s2):
        th = jnp.arctan2(s[1], s[0])
        u = jnp.clip(a[0], -self.max_torque, self.max_torque)
        cost = angle_normalize(th) ** 2 + 0.1 * s[2] ** 2 + 0.001 * u ** 2
        return -cost

    def step(self, state, action):
        cos_th, sin_th, thdot = state
        th = jnp.arctan2(sin_th, cos_th)
        u = jnp.clip(action[0], -self.max_torque, self.max_torque)
        thdot2 = thdot + (3 * self.g / (2 * self.l) * jnp.sin(th)
                          + 3.0 / (self.m * self.l ** 2) * u) * self.dt
        thdot2 = jnp.clip(thdot2, -self.max_speed, self.max_speed)
        th2 = th + thdot2 * self.dt
        ns = jnp.array([jnp.cos(th2), jnp.sin(th2), thdot2])
        return ns, self.reward(state, action, ns)


@dataclasses.dataclass(frozen=True)
class CartpoleSwingup(Env):
    obs_dim: int = 5
    act_dim: int = 1
    horizon: int = 200
    dt: float = 0.05
    name: str = "cartpole_swingup"
    mc: float = 1.0
    mp: float = 0.1
    l: float = 0.5
    g: float = 9.8
    fmax: float = 10.0

    def reset(self, key):
        x = 0.05 * jax.random.normal(key, (4,))
        th = jnp.pi + x[2]  # hanging down
        return jnp.array([x[0], x[1], jnp.cos(th), jnp.sin(th), x[3]])

    def step(self, state, action):
        x, xdot, costh, sinth, thdot = state
        th = jnp.arctan2(sinth, costh)
        f = jnp.clip(action[0], -1, 1) * self.fmax
        mt = self.mc + self.mp
        tmp = (f + self.mp * self.l * thdot ** 2 * sinth) / mt
        thacc = (self.g * sinth - costh * tmp) / (
            self.l * (4.0 / 3.0 - self.mp * costh ** 2 / mt))
        xacc = tmp - self.mp * self.l * thacc * costh / mt
        x = x + xdot * self.dt
        xdot = xdot + xacc * self.dt
        th = th + thdot * self.dt
        thdot = thdot + thacc * self.dt
        ns = jnp.array([x, xdot, jnp.cos(th), jnp.sin(th), thdot])
        return ns, self.reward(state, action, ns)

    def reward(self, s, a, s2):
        f = jnp.clip(a[0], -1, 1) * self.fmax
        return s2[2] - 0.01 * s2[0] ** 2 - 0.001 * f ** 2 \
            - 0.001 * s2[4] ** 2


@dataclasses.dataclass(frozen=True)
class SpringHopper(Env):
    """1-D hopper: mass on an actuated spring leg; reward = forward hop
    velocity while staying alive. A cheap stand-in for locomotion tasks."""
    obs_dim: int = 4
    act_dim: int = 1
    horizon: int = 200
    dt: float = 0.02
    name: str = "spring_hopper"
    g: float = 9.8
    k_spring: float = 80.0
    m: float = 1.0

    def reset(self, key):
        z = 1.0 + 0.05 * jax.random.normal(key, ())
        return jnp.array([0.0, z, 0.0, 0.0])  # x, z, xdot, zdot

    def step(self, state, action):
        x, z, xdot, zdot = state
        u = jnp.clip(action[0], -1, 1)
        contact = z < 0.5
        f_spring = jnp.where(contact, self.k_spring * (0.5 - z) * (1 + u), 0.0)
        f_fwd = jnp.where(contact, 3.0 * u, 0.0)
        zacc = f_spring / self.m - self.g
        xacc = f_fwd / self.m - 0.5 * xdot
        x = x + xdot * self.dt
        z = jnp.clip(z + zdot * self.dt, 0.05, 3.0)
        xdot = xdot + xacc * self.dt
        zdot = jnp.where(z <= 0.05, jnp.maximum(zdot + zacc * self.dt, 0.0),
                         zdot + zacc * self.dt)
        ns = jnp.array([x, z, xdot, zdot])
        return ns, self.reward(state, action, ns)

    def reward(self, s, a, s2):
        u = jnp.clip(a[0], -1, 1)
        return s2[2] - 0.001 * u ** 2 + 0.1 * jnp.clip(s2[1], 0, 1)
