"""Torque-controlled planar/spatial arms with the paper's PR2 reward.

``Reacher2`` is a 2-link planar arm; ``Arm7`` mirrors the paper's PR2
setup: 7 joints, torque control at 10 Hz, 23-D observation (7 angles,
7 velocities, 9 Cartesian points of the end-effector frame), and reward

    r(d) = -omega * d^2 - v * log(d^2 + alpha)        (omega=v=1, a=1e-5)

plus scaled quadratic penalties on joint velocities and torques (§5.5).
Tasks (reach / shape-match / lego-stack) differ only in target and
tolerance, exactly as in the paper where objects are treated as fixed
end-effector extensions."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.envs.base import Env


def lorentzian_reward(d2, omega=1.0, v=1.0, alpha=1e-5):
    return -omega * d2 - v * jnp.log(d2 + alpha)


@dataclasses.dataclass(frozen=True)
class Reacher2(Env):
    obs_dim: int = 8   # cos2, sin2, qdot2, fingertip xy
    act_dim: int = 2
    horizon: int = 100
    dt: float = 0.05
    name: str = "reacher2"
    l1: float = 0.5
    l2: float = 0.5
    target: tuple = (0.6, 0.4)

    def _tip(self, q):
        x = self.l1 * jnp.cos(q[0]) + self.l2 * jnp.cos(q[0] + q[1])
        y = self.l1 * jnp.sin(q[0]) + self.l2 * jnp.sin(q[0] + q[1])
        return jnp.array([x, y])

    def _obs(self, q, qd):
        tip = self._tip(q)
        return jnp.concatenate([jnp.cos(q), jnp.sin(q), qd, tip])

    def reset(self, key):
        q = jax.random.uniform(key, (2,), minval=-0.1, maxval=0.1)
        return self._obs(q, jnp.zeros(2))

    def step(self, state, action):
        q = jnp.arctan2(state[2:4], state[0:2])
        qd = state[4:6]
        u = jnp.clip(action, -1, 1)
        qdd = 4.0 * u - 0.5 * qd      # damped double integrator per joint
        qd = jnp.clip(qd + qdd * self.dt, -8, 8)
        q = q + qd * self.dt
        ns = self._obs(q, qd)
        return ns, self.reward(state, action, ns)

    def reward(self, s, a, s2):
        u = jnp.clip(a, -1, 1)
        tip = s2[6:8]
        d2 = jnp.sum((tip - jnp.asarray(self.target)) ** 2)
        return lorentzian_reward(d2) - 0.01 * jnp.sum(s2[4:6] ** 2) \
            - 0.001 * jnp.sum(u ** 2)


_PR2_TASKS = {
    # target xyz in the arm frame; tolerance used only for reporting
    "reach": (jnp.array([0.5, 0.2, 0.3]), 0.02),
    "shape_match": (jnp.array([0.45, -0.1, 0.15]), 0.01),
    "lego_stack": (jnp.array([0.4, 0.15, 0.1]), 0.005),
}


@dataclasses.dataclass(frozen=True)
class Arm7(Env):
    obs_dim: int = 23  # 7q + 7qd + 9 cartesian points (3 frame points x 3)
    act_dim: int = 7
    horizon: int = 100
    dt: float = 0.1     # 10 Hz, as on the PR2
    name: str = "arm7_reach"
    task: str = "reach"
    link: float = 0.18

    def _fk(self, q):
        """Simple spatial FK: alternating z/y rotation axes down the chain.
        Returns end-effector origin + two frame points (9 numbers)."""
        p = jnp.zeros(3)
        R = jnp.eye(3)
        for i in range(7):
            axis = i % 2  # 0: rotate about z, 1: about y
            c, s = jnp.cos(q[i]), jnp.sin(q[i])
            Rz = jnp.array([[c, -s, 0], [s, c, 0], [0, 0, 1]])
            Ry = jnp.array([[c, 0, s], [0, 1, 0], [-s, 0, c]])
            R = R @ jnp.where(axis == 0, Rz, Ry)
            p = p + R @ jnp.array([self.link, 0.0, 0.0])
        tip = p
        fx = p + 0.05 * R[:, 0]
        fy = p + 0.05 * R[:, 1]
        return jnp.concatenate([tip, fx, fy])

    def _obs(self, q, qd):
        return jnp.concatenate([q, qd, self._fk(q)])

    def reset(self, key):
        q = 0.1 * jax.random.normal(key, (7,))
        return self._obs(q, jnp.zeros(7))

    def step(self, state, action):
        q, qd = state[:7], state[7:14]
        u = jnp.clip(action, -1, 1)
        qdd = 6.0 * u - 1.0 * qd - 0.3 * jnp.sin(q)  # gravity-ish bias
        qd = jnp.clip(qd + qdd * self.dt, -4, 4)
        q = jnp.clip(q + qd * self.dt, -2.8, 2.8)
        ns = self._obs(q, qd)
        return ns, self.reward(state, action, ns)

    def reward(self, s, a, s2):
        u = jnp.clip(a, -1, 1)
        target, _tol = _PR2_TASKS[self.task]
        d2 = jnp.sum((s2[14:17] - target) ** 2)
        return lorentzian_reward(d2) - 0.05 * jnp.sum(s2[7:14] ** 2) \
            - 0.01 * jnp.sum(u ** 2)

    def distance(self, state):
        target, _ = _PR2_TASKS[self.task]
        return jnp.linalg.norm(state[14:17] - target)


def make_env(name: str) -> Env:
    from repro.envs.classic import CartpoleSwingup, Pendulum, SpringHopper
    table = {
        "pendulum": Pendulum(),
        "cartpole_swingup": CartpoleSwingup(),
        "spring_hopper": SpringHopper(),
        "reacher2": Reacher2(),
        "pr2_reach": Arm7(task="reach", name="arm7_reach"),
        "pr2_shape_match": Arm7(task="shape_match", name="arm7_shape"),
        "pr2_lego_stack": Arm7(task="lego_stack", name="arm7_lego"),
    }
    return table[name]
