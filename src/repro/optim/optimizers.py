"""Minimal functional optimizer library (optax is not installed offline).

An ``Optimizer`` is an (init, update) pair operating on pytrees:

    opt = adam(3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# ----------------------------------------------------------------- schedules
def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, decay_steps: int, final_frac: float = 0.0):
    def f(step):
        t = jnp.clip(step / max(decay_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return f


def warmup_cosine(lr: float, warmup_steps: int, decay_steps: int,
                  final_frac: float = 0.0):
    cos = cosine_schedule(lr, max(decay_steps - warmup_steps, 1), final_frac)
    def f(step):
        warm = lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return f


def _as_schedule(lr):
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------- optimizers
class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), zeros,
                         jax.tree.map(jnp.copy, zeros))

    def update(grads, state: AdamState, params=None):
        step = state.step + 1
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = sched(step)

        def upd(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        else:
            updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step, mu, nu)

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        mom = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return SGDState(jnp.zeros((), jnp.int32), mom)

    def update(grads, state: SGDState, params=None):
        step = state.step + 1
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                               state.momentum, grads)
        else:
            mom = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        lr_t = sched(step)
        updates = jax.tree.map(lambda m: -lr_t * m, mom)
        return updates, SGDState(step, mom)

    return Optimizer(init, update)


def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""
    def update(grads, state, params=None):
        norm = jnp.sqrt(sum(jnp.vdot(g, g).real
                            for g in jax.tree.leaves(grads)) + 1e-12)
        scale = jnp.minimum(1.0, max_norm / norm).astype(jnp.float32)
        grads = jax.tree.map(lambda g: g * scale, grads)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)
