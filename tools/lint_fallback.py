"""Stdlib-only fallback for `make lint` on hosts without ruff.

Approximates the enforced rule set (pyflakes F + E9 + import sorting I,
see pyproject.toml [tool.ruff]): syntax errors, unused imports (F401),
duplicate function/class definitions in one scope (F811-lite), and
unsorted import blocks (I001-lite: future < stdlib < third-party <
first-party sections, straight imports before from-imports, modules
alphabetical case-insensitively). It intentionally under-reports
relative to ruff — CI installs the real linter from
requirements-dev.txt; this keeps local `make lint` from silently
becoming a no-op.

Usage: python tools/lint_fallback.py DIR [DIR ...]
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

SKIP_DIRS = {"__pycache__", "results", ".git"}

# mirrors [tool.ruff] src: repo-local packages/modules sort last
FIRST_PARTY = {"repro", "benchmarks", "tools", "tests",
               "_hypothesis_compat", "_mesh_impl", "conftest"}
_STDLIB = getattr(sys, "stdlib_module_names", frozenset())


def _import_sort_key(node):
    """(section, style, module-lower): the order ruff's default isort
    profile enforces within one contiguous import block."""
    if isinstance(node, ast.Import):
        module, style = node.names[0].name, 0
    else:
        module = "." * node.level + (node.module or "")
        style = 1
    root = module.lstrip(".").split(".")[0]
    if module.startswith("__future__"):
        section = 0
    elif module.startswith("."):
        section = 4         # relative (local-folder) imports sort LAST
    elif root in _STDLIB:
        section = 1
    elif root in FIRST_PARTY:
        section = 3
    else:
        section = 2
    return (section, style, module.lower())


def _check_import_order(path, tree):
    """I001-lite: every contiguous run of import statements (any scope)
    must already be in sorted order."""
    problems = []
    for scope in ast.walk(tree):
        body = getattr(scope, "body", None)
        if not isinstance(body, list) or isinstance(scope, ast.Try):
            continue        # try/except import fallbacks are deliberate
        run = []
        for node in list(body) + [None]:
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                run.append(node)
                continue
            if len(run) > 1:
                keys = [_import_sort_key(n) for n in run]
                if keys != sorted(keys):
                    bad = next(i for i in range(len(keys) - 1)
                               if keys[i] > keys[i + 1])
                    problems.append(
                        f"{path}:{run[bad + 1].lineno}: I001 import block "
                        "un-sorted (section/style/alpha order)")
            run = []
    return problems


def _imported_names(node):
    """(alias, lineno) pairs bound by an import statement."""
    out = []
    for alias in node.names:
        name = alias.asname or alias.name.split(".")[0]
        if name != "*":
            out.append((name, node.lineno))
    return out


def check_file(path: Path):
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [f"{path}:{e.lineno}: E999 syntax error: {e.msg}"]

    problems = _check_import_order(path, tree)
    # F401: names bound by module-level imports and never read anywhere.
    # Conservative: any attribute/name/string occurrence counts as use
    # (docstring-referenced re-exports are common in this repo).
    imports = []
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            if isinstance(node, ast.ImportFrom) and node.module == "__future__":
                continue
            imports.extend(_imported_names(node))
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            used.add(node.attr)
    has_all = any(isinstance(n, ast.Assign) and any(
        getattr(t, "id", None) == "__all__" for t in n.targets)
        for n in tree.body)
    is_pkg_init = path.name == "__init__.py"
    if not (has_all or is_pkg_init):    # re-export surfaces exempt
        for name, lineno in imports:
            if name not in used:
                problems.append(
                    f"{path}:{lineno}: F401 '{name}' imported but unused")

    # F811-lite: same def/class name bound twice in one scope
    for scope in ast.walk(tree):
        if not isinstance(scope, (ast.Module, ast.ClassDef,
                                  ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        seen = {}
        for node in getattr(scope, "body", []):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                if node.name in seen and not any(
                        isinstance(d, ast.Name) and d.id in
                        ("overload", "property")
                        or isinstance(d, ast.Attribute)
                        for d in node.decorator_list):
                    problems.append(
                        f"{path}:{node.lineno}: F811 redefinition of "
                        f"'{node.name}' (line {seen[node.name]})")
                seen[node.name] = node.lineno
    return problems


def main(argv) -> int:
    roots = [Path(a) for a in argv] or [Path("src")]
    problems = []
    for root in roots:
        files = [root] if root.is_file() else sorted(
            p for p in root.rglob("*.py")
            if not SKIP_DIRS & set(q.name for q in p.parents))
        for f in files:
            problems.extend(check_file(f))
    for p in problems:
        print(p)
    print(f"lint_fallback: {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
