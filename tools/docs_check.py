#!/usr/bin/env python
"""Stdlib link-and-anchor checker for the repo's markdown tier.

Walks README.md plus every ``docs/*.md`` (and any extra paths given on
the command line) and fails (exit 1) on:

* relative links to files that don't exist (``[x](docs/FOO.md)``,
  ``[x](../README.md)``);
* fragment links whose anchor matches no heading in the target file
  (``[x](ARCHITECTURE.md#mode-matrix)``, ``[x](#local-heading)``),
  using GitHub's slug rules (lowercase, punctuation stripped, spaces
  to dashes, ``-N`` suffixes for duplicates);
* reference-style links (``[x][ref]``) with no matching definition.

External links (http/https/mailto) are deliberately NOT fetched — CI
must not flake on the internet; they are only syntax-checked. Fenced
code blocks and inline code spans are stripped first so shell snippets
never false-positive.

Usage:  python tools/docs_check.py [root] [extra.md ...]
        make docs-check
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import Dict, List, Set, Tuple

_FENCE_RE = re.compile(r"^(```|~~~)")
_CODE_SPAN_RE = re.compile(r"`[^`]*`")
# [text](target) — target may carry an optional "title"
_INLINE_LINK_RE = re.compile(r"\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
# [text][ref] and [ref]: definition lines
_REF_LINK_RE = re.compile(r"\[[^\]]+\]\[([^\]]+)\]")
_REF_DEF_RE = re.compile(r"^\s*\[([^\]]+)\]:\s*(\S+)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")


def strip_code(text: str) -> List[str]:
    """Markdown source -> lines with fenced blocks and inline code spans
    blanked (line count preserved so reports stay line-accurate)."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            out.append("")
            continue
        out.append("" if in_fence else _CODE_SPAN_RE.sub("", line))
    return out


def github_slug(heading: str, seen: Dict[str, int]) -> str:
    """GitHub's anchor algorithm: strip markdown emphasis/code/links,
    lowercase, drop punctuation, spaces->dashes, -N for duplicates."""
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)   # links -> text
    h = re.sub(r"[`*_]", "", h).strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    slug = h.replace(" ", "-")
    n = seen.get(slug, 0)
    seen[slug] = n + 1
    return slug if n == 0 else f"{slug}-{n}"


def anchors_of(path: Path, cache: Dict[Path, Set[str]]) -> Set[str]:
    if path not in cache:
        seen: Dict[str, int] = {}
        slugs: Set[str] = set()
        for line in strip_code(path.read_text(encoding="utf-8")):
            m = _HEADING_RE.match(line)
            if m:
                slugs.add(github_slug(m.group(2), seen))
        cache[path] = slugs
    return cache[path]


def check_file(md: Path, root: Path,
               anchor_cache: Dict[Path, Set[str]]) -> List[str]:
    errors: List[str] = []
    text = md.read_text(encoding="utf-8")
    lines = strip_code(text)

    ref_defs: Set[str] = set()
    links: List[Tuple[int, str]] = []
    for i, line in enumerate(lines, 1):
        d = _REF_DEF_RE.match(line)
        if d:
            ref_defs.add(d.group(1).lower())
            continue
        for m in _INLINE_LINK_RE.finditer(line):
            links.append((i, m.group(1)))
        for m in _REF_LINK_RE.finditer(line):
            links.append((i, f"ref:{m.group(1).lower()}"))

    for lineno, target in links:
        where = f"{md.relative_to(root)}:{lineno}"
        if target.startswith("ref:"):
            if target[4:] not in ref_defs:
                errors.append(f"{where}: undefined link reference "
                              f"[{target[4:]}]")
            continue
        if re.match(r"^[a-zA-Z][a-zA-Z0-9+.-]*:", target):   # http:, mailto:
            continue
        path_part, _, frag = target.partition("#")
        dest = md if not path_part else (md.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{where}: broken link -> {target} "
                          f"(no such file {path_part})")
            continue
        if frag:
            if dest.is_dir() or dest.suffix.lower() not in (".md", ".markdown"):
                continue                     # can't anchor-check non-markdown
            if frag.lower() not in anchors_of(dest, anchor_cache):
                errors.append(f"{where}: broken anchor -> {target} "
                              f"(no heading slugs to '#{frag}')")
    return errors


def main(argv: List[str]) -> int:
    root = Path(argv[1]).resolve() if len(argv) > 1 else Path.cwd()
    targets = [root / "README.md", *sorted((root / "docs").glob("*.md")),
               *(root / a for a in argv[2:])]
    targets = [t for t in targets if t.exists()]
    if not targets:
        print(f"docs-check: nothing to check under {root}", file=sys.stderr)
        return 1

    cache: Dict[Path, Set[str]] = {}
    errors: List[str] = []
    for md in targets:
        errors.extend(check_file(md, root, cache))

    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    print(f"docs-check: {len(targets)} files, "
          f"{len(errors)} broken link(s)/anchor(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
