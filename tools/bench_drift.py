#!/usr/bin/env python
"""Diff BENCH_*.json metric trajectories and flag drift beyond
host-noise bands (PR 7 — the PR 5 re-baseline caveat, made mechanical).

Benchmark numbers move for two very different reasons: host noise
(shared runners, turbo states, cache weather) and real regressions. The
repo's committed baselines get re-measured whenever the bench harness
itself changes shape, so "the number changed" alone is meaningless —
what matters is whether it changed by MORE than that metric's expected
noise. This tool encodes those bands:

* counts (``*_compiles*``, ``*_steps``, anything integer-exact) —
  band 0%: any change is drift (a compile count has no noise);
* latencies (``*_us``, ``*_wall_s``) — 25%;
* rates (``*_per_s``, ``*_speedup_x``) — 30% (throughputs wobble more:
  they compound scheduler + queue effects);
* ``imagine_fused_*_speedup_x`` — exact-floored: the fused-vs-legacy
  imagination ratio (ISSUE 10) is a back-to-back measurement on one
  host, so host noise largely cancels; DROPPING below the committed
  ratio is drift at any magnitude, while getting faster never is;
* everything else numeric — 30%;
* boolean invariants — any flip is drift.

Usage::

    python tools/bench_drift.py BENCH_hotpath.json fresh.json
    python tools/bench_drift.py a.json b.json c.json   # trajectory:
                                                       # consecutive pairs
    python tools/bench_drift.py --strict ...           # exit 1 on drift
    python tools/bench_drift.py --json drift.json ...

Exit status: 0 (no drift, or drift found but not --strict), 1 (drift
with --strict), 2 (usage/load error). CI runs it informationally on
every PR and strictly in the scheduled soak job.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Tuple

LATENCY_BAND = 0.25
RATE_BAND = 0.30
DEFAULT_BAND = 0.30


def band_for(name: str, value: Any) -> float:
    """Relative noise band for one metric; 0.0 means exact."""
    if isinstance(value, bool):
        return 0.0
    if "compiles" in name or name.endswith("_steps"):
        return 0.0
    if isinstance(value, int):
        return 0.0
    if name.endswith("_us") or name.endswith("_wall_s") \
            or name.endswith("_s"):
        return LATENCY_BAND
    if name.endswith("_per_s") or name.endswith("_speedup_x"):
        return RATE_BAND
    return DEFAULT_BAND


def _numbers(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Flatten the comparable scalars of one BENCH json: metrics plus
    boolean invariants."""
    out: Dict[str, Any] = {}
    for k, v in (doc.get("invariants") or {}).items():
        out[f"invariants.{k}"] = v
    for k, v in (doc.get("metrics") or {}).items():
        if isinstance(v, (int, float, bool)):
            out[k] = v
    return out


def diff_pair(a_doc: Dict[str, Any], b_doc: Dict[str, Any]
              ) -> List[Dict[str, Any]]:
    """All drifting metrics between two BENCH documents."""
    a, b = _numbers(a_doc), _numbers(b_doc)
    findings = []
    for name in sorted(set(a) & set(b)):
        va, vb = a[name], b[name]
        band = band_for(name, va)
        if isinstance(va, bool) or isinstance(vb, bool):
            drifted = bool(va) != bool(vb)
            rel = None
        elif name.startswith("imagine_fused_") \
                and name.endswith("_speedup_x"):
            # exact-floored ratio: only a decrease is drift
            band = 0.0
            drifted = float(vb) < float(va)
            rel = (None if not drifted else
                   (float(va) - float(vb)) / max(abs(float(va)), 1e-12))
        elif band == 0.0:
            drifted = va != vb
            rel = None
        else:
            ref = max(abs(float(va)), 1e-12)
            rel = abs(float(vb) - float(va)) / ref
            drifted = rel > band
        if drifted:
            findings.append({
                "metric": name, "before": va, "after": vb,
                "rel_change": None if rel is None else round(rel, 4),
                "band": band})
    return findings


def load(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python tools/bench_drift.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("files", nargs="+",
                    help="two or more BENCH_*.json files, oldest first")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when any metric drifts beyond its band")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also write the findings as JSON")
    args = ap.parse_args(argv)
    if len(args.files) < 2:
        ap.error("need at least two files to diff")
    try:
        docs = [(p, load(p)) for p in args.files]
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_drift: cannot load input: {e}", file=sys.stderr)
        return 2

    steps: List[Dict[str, Any]] = []
    total = 0
    for (pa, da), (pb, db) in zip(docs, docs[1:]):
        findings = diff_pair(da, db)
        total += len(findings)
        steps.append({"before": pa, "after": pb, "drift": findings})
        header = f"{pa} -> {pb}"
        if not findings:
            print(f"{header}: no drift beyond noise bands")
            continue
        print(f"{header}: {len(findings)} metric(s) drifted")
        for f in findings:
            rel = ("exact" if f["rel_change"] is None
                   else f"{100 * f['rel_change']:.1f}% "
                        f"(band {100 * f['band']:.0f}%)")
            print(f"  {f['metric']}: {f['before']} -> {f['after']} "
                  f"[{rel}]")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"steps": steps, "total_drifting": total}, f,
                      indent=2)
            f.write("\n")
    return 1 if (args.strict and total) else 0


if __name__ == "__main__":
    sys.exit(main())
