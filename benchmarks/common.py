"""Shared benchmark harness: builds engines, caches traces to JSON."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

RESULTS = Path(__file__).parent / "results"
RESULTS.mkdir(exist_ok=True)


def build_algo(env, algo_name, *, n_models=3, imagine_batch=48,
               imagine_horizon=40, model_hidden=96, policy_hidden=48):
    from repro.mbrl import AlgoConfig, EnsembleConfig, PolicyConfig, make_algo
    ens = EnsembleConfig(env.obs_dim, env.act_dim, hidden=model_hidden,
                         n_models=n_models)
    pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=policy_hidden)
    acfg = AlgoConfig(algo=algo_name, imagine_batch=imagine_batch,
                      imagine_horizon=imagine_horizon, n_models=n_models)
    algo = make_algo(acfg, pol, jax.vmap(env.reward), env.reset_batch)
    # acfg rides along for mode="procs" engines, whose children rebuild
    # the algo from plain configs
    return ens, pol, acfg, algo


def run_engine(env_name, algo_name, engine, *, trajs=20, seed=0, tag="",
               cache=True, **rc_kw):
    """Run one (env, algo, engine) combo; returns the eval trace.
    Results cached in benchmarks/results/."""
    from repro.core import (AsyncTrainer, PartialAsyncDataPolicy,
                            PartialAsyncModelPolicy, RunConfig,
                            SequentialTrainer)
    from repro.envs import make_env
    from repro.mbrl.model_free import ModelFreeTrainer
    from repro.mbrl.policy import PolicyConfig

    name = f"{env_name}_{algo_name}_{engine}_{trajs}_{seed}{tag}"
    path = RESULTS / f"{name}.json"
    if cache and path.exists():
        return json.loads(path.read_text())

    env = make_env(env_name)
    rc = RunConfig(total_trajs=trajs, seed=seed, **rc_kw)
    t0 = time.perf_counter()  # monotonic: an NTP step must not skew this
    if engine.startswith("mf-"):
        pol = PolicyConfig(env.obs_dim, env.act_dim, hidden=48)
        tr = ModelFreeTrainer(env, pol, rc, algo=engine[3:])
        trace = tr.run()
    else:
        ens, pol, _acfg, algo = build_algo(env, algo_name)
        eng = {"async": AsyncTrainer, "sequential": SequentialTrainer,
               "partial-model": PartialAsyncModelPolicy,
               "partial-data": PartialAsyncDataPolicy}[engine]
        trace = eng(env, ens, algo, rc).run()
    out = {"env": env_name, "algo": algo_name, "engine": engine,
           "trajs": trajs, "seed": seed,
           "real_seconds": round(time.perf_counter() - t0, 1),
           "trace": trace}
    path.write_text(json.dumps(out, indent=1))
    return out


def time_to_threshold(trace, threshold):
    """First virtual time at which eval_return >= threshold (None if never)."""
    for r in trace:
        if r["eval_return"] >= threshold:
            return r["time"]
    return None


def best_return(trace):
    return max(r["eval_return"] for r in trace)


def final_time(trace):
    return trace[-1]["time"]


def auc_return(trace, x="time"):
    """Area under the (x, return) curve — sample-efficiency summary."""
    if len(trace) < 2:
        return trace[0]["eval_return"] if trace else 0.0
    tot, span = 0.0, 0.0
    for a, b in zip(trace[:-1], trace[1:]):
        dx = b[x] - a[x]
        tot += 0.5 * (a["eval_return"] + b["eval_return"]) * dx
        span += dx
    return tot / max(span, 1e-9)
