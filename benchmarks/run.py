"""Benchmark harness — one function per paper figure/table.

Prints ``name,value,derived`` CSV rows. MBRL figures use the deterministic
discrete-event engine (virtual robot-time, §5.1 methodology); the roofline
table reads the dry-run JSON produced by repro.launch.dryrun.

  python -m benchmarks.run [--full] [--only fig2,roofline]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks import common as C

ROWS = []


def emit(name, value, derived=""):
    ROWS.append((name, value, derived))
    print(f"{name},{value},{derived}", flush=True)


# ------------------------------------------------------------ Fig. 2 + 3
def fig2_fig3_wallclock_and_samples(full: bool):
    """Wall-clock speedup (Fig 2) and sample complexity (Fig 3):
    async vs sequential vs model-free, two envs."""
    trajs = 40 if full else 24
    envs = ["pendulum", "reacher2"] if not full else \
        ["pendulum", "reacher2", "cartpole_swingup", "spring_hopper"]
    algos = ["me-trpo", "me-ppo", "mb-mpo"] if full else ["me-trpo"]
    for env in envs:
        for algo in algos:
            a = C.run_engine(env, algo, "async", trajs=trajs)
            s = C.run_engine(env, algo, "sequential", trajs=trajs)
            speedup = C.final_time(s["trace"]) / max(
                C.final_time(a["trace"]), 1e-9)
            emit(f"fig2/{env}/{algo}/async_final_time_s",
                 round(C.final_time(a["trace"]), 1),
                 f"best_return={C.best_return(a['trace']):.1f}")
            emit(f"fig2/{env}/{algo}/sequential_final_time_s",
                 round(C.final_time(s["trace"]), 1),
                 f"best_return={C.best_return(s['trace']):.1f}")
            emit(f"fig2/{env}/{algo}/wallclock_speedup_x",
                 round(speedup, 2), "async vs sequential to same #trajs")
            emit(f"fig3/{env}/{algo}/async_auc_return",
                 round(C.auc_return(a["trace"], "env_steps"), 1),
                 "sample-complexity AUC (higher=better)")
            emit(f"fig3/{env}/{algo}/sequential_auc_return",
                 round(C.auc_return(s["trace"], "env_steps"), 1), "")
        mf = C.run_engine(env, "none", "mf-ppo", trajs=trajs)
        emit(f"fig3/{env}/model-free-ppo_auc_return",
             round(C.auc_return(mf["trace"], "env_steps"), 1),
             f"best={C.best_return(mf['trace']):.1f}")


# ---------------------------------------------------------------- Fig. 4
def _fig4(engine, key, label, full):
    """Seed-averaged ablation (the paper averages 4 seeds)."""
    import numpy as np
    trajs = 24 if full else 16
    seeds = (0, 1, 2)
    for env in ["reacher2"] + (["pendulum"] if full else []):
        pa = [C.auc_return(C.run_engine(env, "me-trpo", engine, trajs=trajs,
                                        seed=sd)["trace"], "env_steps")
              for sd in seeds]
        sa = [C.auc_return(C.run_engine(env, "me-trpo", "sequential",
                                        trajs=trajs, seed=sd)["trace"],
                           "env_steps")
              for sd in seeds]
        emit(f"{key}/{env}/{engine}_auc_mean", round(float(np.mean(pa)), 1),
             f"{label}; seeds={list(seeds)} std={np.std(pa):.1f}")
        emit(f"{key}/{env}/sequential_auc_mean", round(float(np.mean(sa)), 1),
             f"in-order; std={np.std(sa):.1f}")


def fig4a_interleave_model(full: bool):
    _fig4("partial-model", "fig4a", "interleaved model+policy updates", full)


def fig4b_interleave_data(full: bool):
    _fig4("partial-data", "fig4b", "interleaved collection+policy updates",
          full)


# ---------------------------------------------------------------- Fig. 5
def fig5a_early_stopping(full: bool):
    """Early stopping matters when collection is SLOW relative to model
    training (paper: 'for low-data-frequency tasks ... early stopping is
    crucial'), so this ablation runs at 1/3 collection speed."""
    trajs = 20 if full else 12
    for w in (0.5, 0.9, 0.99):
        r = C.run_engine("reacher2", "me-trpo", "async", trajs=trajs,
                         tag=f"_ema{w}", ema_weight=w, collect_speed=0.33)
        emit(f"fig5a/reacher2/ema_{w}_best_return",
             round(C.best_return(r["trace"]), 1),
             "lower weight = more aggressive early stop; slow collection")


def fig5b_sampling_speed(full: bool):
    import numpy as np
    trajs = 20 if full else 16
    seeds = (0, 1, 2)
    for sp in (0.5, 1.0, 2.0):
        aucs = [C.auc_return(
            C.run_engine("reacher2", "me-trpo", "async", trajs=trajs,
                         tag=f"_speed{sp}", collect_speed=sp,
                         seed=sd)["trace"], "env_steps") for sd in seeds]
        emit(f"fig5b/reacher2/collect_speed_{sp}_auc_mean",
             round(float(np.mean(aucs)), 1),
             f"slower collection -> more grad steps/sample; "
             f"std={np.std(aucs):.1f}")


# ---------------------------------------------------------------- Fig. 7
def fig7_pr2_tasks(full: bool):
    trajs = 24 if full else 12
    for task in ("pr2_reach", "pr2_shape_match", "pr2_lego_stack"):
        algo = "mb-mpo" if full else "me-trpo"   # paper uses asynch-MB-MPO
        r = C.run_engine(task, algo, "async", trajs=trajs)
        emit(f"fig7/{task}/virtual_minutes",
             round(C.final_time(r["trace"]) / 60.0, 1),
             f"best_return={C.best_return(r['trace']):.1f}")


# -------------------------------------------------------------- roofline
def roofline(full: bool):
    from benchmarks.roofline import roofline_table
    path = Path(__file__).parent.parent / "dryrun_results.json"
    if not path.exists():
        emit("roofline/status", "missing",
             "run python -m repro.launch.dryrun --all first")
        return
    rows = roofline_table(json.loads(path.read_text()))
    for r in rows:
        if not r.get("ok"):
            continue
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/bound",
             r["bottleneck"],
             f"compute={r['t_compute_ms']:.2f}ms "
             f"memory={r['t_memory_ms']:.2f}ms "
             f"collective={r['t_collective_ms']:.2f}ms "
             f"useful_flop_frac={r['useful_flop_frac']}")


# --------------------------------------------------------------- hotpath
def hotpath(full: bool):
    """Steady-state async hot-path latencies + retrace counts (see
    benchmarks/hotpath.py). Measurement only: the committed
    BENCH_hotpath.json baseline is seeded if absent but never
    overwritten here — updating/gating it is `make bench-hotpath`'s
    job, which checks for regressions first."""
    from benchmarks.hotpath import run_bench, BASELINE
    result = run_bench()
    for k, v in result["metrics"].items():
        emit(f"hotpath/{k}", v)
    emit("hotpath/no_retrace_after_warmup",
         result["invariants"]["no_retrace_after_warmup"],
         "train_epoch must compile exactly once")
    if not BASELINE.exists():
        BASELINE.write_text(json.dumps(result, indent=1) + "\n")


# ------------------------------------------------------- kernel micro
def kernel_micro(full: bool):
    """Reference-path kernel microbenchmarks (CPU; relative numbers)."""
    import time
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.ssd import ops as ssd
    k = jax.random.key(0)
    q = jax.random.normal(k, (1, 1024, 8, 64), jnp.float32)
    kk = jax.random.normal(k, (1, 1024, 2, 64), jnp.float32)
    f = jax.jit(lambda q, kk: fa.attention(q, kk, kk))
    f(q, kk).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        f(q, kk).block_until_ready()
    emit("kernel/chunked_attention_1k_us",
         round((time.perf_counter() - t0) / 3 * 1e6), "ref path, CPU")
    x = jax.random.normal(k, (1, 1024, 8, 32), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(k, (1, 1024, 8)))
    A = -jnp.ones((8,))
    B = jax.random.normal(k, (1, 1024, 1, 32)) * 0.3
    g = jax.jit(lambda *a: ssd.ssd(*a))
    g(x, dt, A, B, B).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        g(x, dt, A, B, B).block_until_ready()
    emit("kernel/ssd_1k_us", round((time.perf_counter() - t0) / 3 * 1e6),
         "ref path, CPU")


BENCHES = {
    "fig2": fig2_fig3_wallclock_and_samples,
    "fig4a": fig4a_interleave_model,
    "fig4b": fig4b_interleave_data,
    "fig5a": fig5a_early_stopping,
    "fig5b": fig5b_sampling_speed,
    "fig7": fig7_pr2_tasks,
    "roofline": roofline,
    "kernel": kernel_micro,
    "hotpath": hotpath,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,value,derived")
    for n in names:
        BENCHES[n](args.full)
        # each figure sweeps its own env variants; drop their compiled
        # eval programs so a long --full run can't grow the cache
        from repro.core import clear_eval_cache
        clear_eval_cache()
    out = Path(__file__).parent / "results" / "summary.csv"
    out.parent.mkdir(exist_ok=True)
    out.write_text("name,value,derived\n" + "\n".join(
        f"{a},{b},{c}" for a, b, c in ROWS) + "\n")


if __name__ == "__main__":
    main()
