"""Steady-state hot-path benchmark + regression gate.

Measures what the async engine's retrace-free, zero-copy plumbing is
supposed to guarantee (and what the seed code violated):

* per-step steady-state latency of each worker (collect one trajectory /
  one model epoch / one policy-improvement step);
* retrace counts: the ring trainer's ``train_epoch`` must compile ONCE
  across growing buffer fills (seed behavior: one XLA retrace per data
  refresh);
* parameter-server costs: ``pull_if_newer`` on an unchanged version
  (lock + int compare) vs a full ``pull_host`` materialisation;
* end-to-end ``threads``-mode throughput (trajs/s, policy steps/s);
* end-to-end ``procs``-mode throughput (separate OS processes over
  shared-memory stores; ``procs_policy_steps_per_s`` is the post-warmup
  steady-state rate, directly comparable to the threads metric);
* with ``--collect-scaling``: collector-fleet scaling (ISSUE 5) —
  paced trajs/s at N=1,2,4 in threads and procs modes, and the
  event-mode Fig. 4 regeneration (fewer policy steps to the global
  criterion at N>1). Rates/counts only: never gated.
* with ``--env-farm``: vectorized env-farm scaling (ISSUE 6) — paced
  trajs/s at B=1,64,256 envs per collector (threads N=1,2 and procs),
  plus the raw unpaced batch-rollout rate. Rates only: never gated.
* with ``--serve``: serving-tier latency/throughput (ISSUE 8) —
  continuous-batching tokens/s, p50/p95 per-token latency, hot-swap
  stall and the compile-count invariants (serve_* metrics, never
  gated; the compile counts are exact-banded by tools/bench_drift.py).
* with ``--transport``: the PR 9 transport seam — shm vs tcp parameter
  push / changed pull / unchanged-pull-x100 latencies and a tcp data
  round-trip (transport_*_usec metrics, never gated), plus the hard
  zero-array-bytes-on-unchanged-tcp-pull invariant.
* with ``--imagine-fused``: the ISSUE 10 fused-imagination receipt —
  the same rollout timed back-to-back through the legacy two-call scan
  step and the fused ``step_fused`` dispatcher (parity ``_require``d,
  speedup floor 1.15x hard-required; ``imagine_fused_speedup_x`` is
  exact-floored by tools/bench_drift.py).

Run without flags to (re-)write the ``BENCH_hotpath.json`` baseline at
the repo root. With ``--check``, compares fresh numbers against the
committed baseline WITHOUT rewriting it and FAILS (exit 1) on a >20%
latency regression, so the perf trajectory is tracked PR over PR:

  python -m benchmarks.hotpath --check        # or: make bench-hotpath
  python -m benchmarks.hotpath                # re-baseline deliberately

The latencies are absolute wall-clock on the measuring host: the gate is
meaningful on the machine class that produced the baseline. On different
hardware, re-baseline first.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax

REPO_ROOT = Path(__file__).resolve().parent.parent
BASELINE = REPO_ROOT / "BENCH_hotpath.json"
REGRESSION_TOL = 0.20          # fail --check beyond +20% on any _us metric
JITTER_FLOOR_US = 150.0        # minimum absolute slack: for sub-ms
                               # metrics 20% is below scheduler jitter;
                               # any real regression on those paths
                               # (e.g. reintroducing a host copy) is
                               # orders of magnitude, so it still trips
WARMUP = 3
REPS = 20
MICRO_REPS = 100               # sub-ms metrics: min over a longer window
                               # so one background burst can't poison it


def _require(ok, msg):
    """assert that survives python -O: the timed closures' work must not
    silently vanish (stripped asserts would time empty functions)."""
    if not ok:
        raise RuntimeError(msg)


def _timeit(fn, reps=REPS, warmup=WARMUP):
    """Best-case wall latency of fn() in microseconds (block on result).
    Min over reps: the noise-robust estimator for steady-state latency on
    a shared machine — medians swing with background load and would trip
    the 20% regression gate spuriously."""
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        samples.append((time.perf_counter() - t0) * 1e6)
    return round(min(samples), 1)


def _block(x):
    for leaf in jax.tree.leaves(x):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return x


def _build(env_name="pendulum", algo_name="me-trpo"):
    from benchmarks.common import build_algo

    from repro.core import RunConfig
    from repro.envs import make_env
    env = make_env(env_name)
    ens, pol, acfg, algo = build_algo(env, algo_name)
    rc = RunConfig(total_trajs=8, seed=0)
    return env, ens, algo, rc, (pol, acfg)


def bench_worker_steps(metrics):
    """Steady-state per-step latency + retrace counts for all 3 workers."""
    from repro.core import AsyncTrainer
    env, ens, algo, rc, _cfgs = _build()
    tr = AsyncTrainer(env, ens, algo, rc)

    # -- collect: steady-state gated-pull + rollout + zero-copy push
    def one_collect():
        tr.collector.step()
        _block(tr.data_server.drain())
    metrics["collect_step_us"] = _timeit(one_collect, reps=MICRO_REPS)

    # -- model: warm up, then keep feeding data so the buffer keeps
    # growing across epochs — the compile count must stay flat (the seed
    # retraced on every one of these refreshes).
    mw = tr.model_worker
    for _ in range(rc.min_warmup_trajs):
        tr.collector.step()
    mw.step()                         # builds trainer, first compile
    compiles_at_warmup = mw._train_epoch.trace_count
    for _ in range(6):                # growth phase (untimed)
        tr.collector.step()
        mw.stopper.reset()
        _require(mw.step() is not None, "model worker idled mid-growth")
    metrics["train_epoch_compiles_after_warmup"] = \
        mw._train_epoch.trace_count - compiles_at_warmup
    metrics["train_epoch_compiles_total"] = mw._train_epoch.trace_count

    # steady-state epoch latency: no new data, pure drain-check + epoch
    # (mw.step blocks via the float() on the validation loss)
    def one_epoch():
        mw.stopper.reset()
        _require(mw.step() is not None, "model worker idled in timed epoch")
    metrics["model_epoch_us"] = _timeit(one_epoch, reps=10)

    # -- policy step: model server now has params
    pw = tr.policy_worker

    def one_policy_step():
        _require(pw.step(), "policy worker had no model params")
        _block(pw.state["policy"])
    metrics["policy_step_us"] = _timeit(one_policy_step, reps=10)

    # -- imagination breakdown: the rollout alone (sample-then-compute
    # scan), so the rollout-vs-TRPO split of policy_step_us is tracked
    import jax.random as jrandom
    from repro.mbrl.algos import _rollout_with_logp
    algo_obj, rc_key = tr.policy_worker.algo, jrandom.key(0)
    model_params, _ = tr.model_server.pull()
    s0 = algo_obj.init_state_fn(rc_key, algo_obj.cfg.imagine_batch)
    pol = pw.state["policy"]
    roll = jax.jit(lambda mp, pp, s, k: _rollout_with_logp(
        mp, pp, s, k, algo_obj.cfg.imagine_horizon, algo_obj.reward_fn,
        algo_obj.predict_fn))

    def one_imagine():
        _block(roll(model_params, pol, s0, rc_key))
    metrics["imagine_rollout_us"] = _timeit(one_imagine, reps=10)
    return metrics


def bench_imagine_fused(metrics):
    """Fused-imagination speedup (ISSUE 10) — the tentpole's receipt.

    Times the SAME imagined rollout back-to-back through the legacy
    two-call scan step (``PI.sample_with_logp`` + ``predict_assigned``,
    ``fused=False``) and the fused ``DYN.step_fused`` dispatcher, at the
    headline bench sizes, after ``_require``-ing the outputs agree.
    Back-to-back on one host: the ratio is meaningful even when the
    absolute latencies aren't comparable across machines.

    ``imagine_fused_us`` / ``imagine_fused_legacy_us`` ride the 20%
    latency gate like any ``_us`` metric. ``imagine_fused_speedup_x`` is
    the gate the ratio itself answers to: a hard 1.15x floor here, and
    exact-floored drift tracking in tools/bench_drift.py (dropping below
    the committed ratio is drift; getting faster never is)."""
    import jax.numpy as jnp
    import jax.random as jrandom
    from benchmarks.common import build_algo

    from repro.envs import make_env
    from repro.mbrl import dynamics as DYN
    from repro.mbrl import policy as PI
    from repro.mbrl.algos import _rollout_with_logp

    env = make_env("pendulum")
    ens, pol_cfg, acfg, algo = build_algo(env, "me-trpo")
    key = jrandom.key(0)
    mp = DYN.init_ensemble(ens, key)
    pp = PI.init_policy(pol_cfg, key)
    s0 = env.reset_batch(key, acfg.imagine_batch)
    H, rfn = acfg.imagine_horizon, algo.reward_fn

    legacy = jax.jit(lambda m, p, s, k: _rollout_with_logp(
        m, p, s, k, H, rfn, fused=False))
    fused = jax.jit(lambda m, p, s, k: _rollout_with_logp(
        m, p, s, k, H, rfn))

    out_l = _block(legacy(mp, pp, s0, key))
    out_f = _block(fused(mp, pp, s0, key))
    for a, b in zip(out_l, out_f):
        _require(bool(jnp.allclose(a, b, atol=1e-4, rtol=1e-4)),
                 "fused rollout diverged from the legacy path")

    metrics["imagine_fused_us"] = _timeit(
        lambda: _block(fused(mp, pp, s0, key)), reps=10)
    metrics["imagine_fused_legacy_us"] = _timeit(
        lambda: _block(legacy(mp, pp, s0, key)), reps=10)
    speedup = round(metrics["imagine_fused_legacy_us"]
                    / metrics["imagine_fused_us"], 2)
    metrics["imagine_fused_speedup_x"] = speedup
    _require(speedup >= 1.15,
             f"fused imagination speedup {speedup}x below the 1.15x floor")
    return metrics


def bench_parameter_server(metrics):
    """Version-gated pull vs host materialisation."""
    import jax.numpy as jnp
    from repro.core.servers import ParameterServer
    params = {"w": [jnp.ones((256, 256)) for _ in range(4)],
              "b": [jnp.ones((256,)) for _ in range(4)]}
    ps = ParameterServer()
    ver = ps.push(params)

    def gated():
        for _ in range(100):
            v, _ = ps.pull_if_newer(ver)
            _require(v is None, "gated pull returned a value")
    metrics["pull_unchanged_x100_us"] = _timeit(gated, reps=MICRO_REPS)
    metrics["pull_host_us"] = _timeit(lambda: ps.pull_host(),
                                      reps=MICRO_REPS)
    metrics["push_us"] = _timeit(lambda: _block(ps._snapshot(params)),
                                 reps=MICRO_REPS)
    return metrics


def bench_threads_throughput(metrics):
    """End-to-end threads-mode run: real wall time, worker throughputs."""
    from repro.core import AsyncTrainer, RunConfig
    env, ens, algo, _, _cfgs = _build()
    # pace collection at 50x robot speed so the learners actually share
    # the run (unpaced, a simulated pendulum rollout takes ~1ms and the
    # stop criterion fires before the model/policy workers do anything)
    rc = RunConfig(total_trajs=16, seed=0, collect_speed=50.0,
                   pace_collection=True)
    tr = AsyncTrainer(env, ens, algo, rc, mode="threads")
    # pre-warm every compiled path (rollout, train_epoch, improve, eval)
    # so the timed run measures steady state, not first-compile
    for _ in range(rc.min_warmup_trajs):
        tr.collector.step()
    _require(tr.model_worker.step() is not None, "model warmup idled")
    _require(tr.policy_worker.step(), "policy warmup had no model")
    _block(tr.recorder._eval(tr.policy_worker.state["policy"],
                             jax.random.key(0)))
    pre_trajs = tr.collector.collected
    pre_steps = tr.policy_worker.steps
    pre_epochs = tr.model_worker.epochs
    t0 = time.perf_counter()
    tr.run()
    wall = time.perf_counter() - t0
    tr.collector.collected -= pre_trajs
    tr.policy_worker.steps -= pre_steps
    tr.model_worker.epochs -= pre_epochs
    metrics["threads_wall_s"] = round(wall, 3)
    metrics["threads_trajs_per_s"] = round(tr.collector.collected / wall, 2)
    metrics["threads_policy_steps_per_s"] = round(
        tr.policy_worker.steps / wall, 2)
    metrics["threads_model_epochs_per_s"] = round(
        tr.model_worker.epochs / wall, 2)
    return metrics


def bench_procs_throughput(metrics):
    """End-to-end procs-mode run: three spawned OS processes talking
    through shared-memory parameter stores + a trajectory queue.

    Children compile inside the run (a fresh process can't be
    pre-warmed from here), so the steady-state rates are measured over
    the POST-WARMUP window: from the first real policy improvement the
    parent observes (policy server version 2) to run end, using the
    shared version counters. ``procs_wall_s`` keeps the whole run
    including compiles for the record."""
    import threading

    from repro.core import AsyncTrainer, RunConfig
    env, ens, _algo, _, (pol, acfg) = _build()
    rc = RunConfig(total_trajs=16, seed=0, collect_speed=50.0,
                   pace_collection=True, min_warmup_trajs=4,
                   min_final_model_version=1, min_final_policy_version=40)
    tr = AsyncTrainer(env, ens, None, rc, mode="procs",
                      algo_cfg=acfg, pol_cfg=pol)
    done = {}
    th = threading.Thread(target=lambda: done.setdefault("t", tr.run()),
                          daemon=True)
    t_start = time.perf_counter()
    th.start()
    warm = None
    while th.is_alive() and warm is None:
        srv = getattr(tr, "_proc_servers", None)
        if srv and srv["policy"].version >= 2:
            warm = (time.perf_counter(), srv["policy"].version,
                    srv["model"].version)
        else:
            time.sleep(0.005)
    th.join(timeout=900)
    _require(not th.is_alive(), "procs run wedged")
    t_end = time.perf_counter()
    info = tr.proc_info
    metrics["procs_wall_s"] = round(t_end - t_start, 3)
    metrics["procs_trajs_per_s"] = round(
        info["trajs"] / (t_end - t_start), 2)
    if warm is not None:
        t_w, pv_w, mv_w = warm
        span = max(t_end - t_w, 1e-9)
        metrics["procs_policy_steps_per_s"] = round(
            (info["policy_version"] - pv_w) / span, 2)
        metrics["procs_model_epochs_per_s"] = round(
            (info["model_version"] - mv_w) / span, 2)
    else:       # run ended between polls: whole-run fallback
        metrics["procs_policy_steps_per_s"] = round(
            max(info["policy_version"] - 1, 0) / (t_end - t_start), 2)
        metrics["procs_model_epochs_per_s"] = round(
            info["model_version"] / (t_end - t_start), 2)
    return metrics


def bench_collect_scaling(metrics, *, fleet_sizes=(1, 2, 4)):
    """Collector-fleet scaling (ISSUE 5, the paper's Fig. 4 story):

    * threads + procs modes: paced (robot-rate) collection throughput in
      trajs/s at N = 1, 2, 4 — the fleet should scale it ~N× because a
      paced collector sleeps out most of each trajectory;
    * event mode: the async-vs-sync comparison regenerated at N > 1 —
      parallel collection shrinks the virtual collection span, so the
      global stopping criterion is reached in FEWER policy steps.

    All metrics are rates/counts (no ``_us`` suffix), so the >20%%
    latency gate never trips on them — they are tracked PR over PR via
    the committed baseline and the CI artifact."""
    import threading

    from repro.core import AsyncTrainer, RunConfig

    base_trajs = 12              # measured post-warmup window per run

    # -- event mode: policy steps to reach the global criterion
    for n in (1, max(fleet_sizes)):
        env, ens, algo, _, _cfgs = _build()
        tr = AsyncTrainer(env, ens, algo,
                          RunConfig(total_trajs=base_trajs, seed=0),
                          n_collectors=n)
        tr.run()
        _require(tr.data_server.total_pushed == base_trajs,
                 "event fleet criterion not exact")
        metrics[f"collect_scaling_event_n{n}_policy_steps"] = \
            tr.policy_worker.steps
        metrics[f"collect_scaling_event_n{n}_virtual_time_s"] = \
            round(tr.recorder.trace[-1]["time"], 2)

    # -- threads mode: pre-warm every compiled path (each fleet member
    # owns its rollout jit), then time a paced run
    for n in fleet_sizes:
        env, ens, algo, _, _cfgs = _build()
        rc = RunConfig(total_trajs=base_trajs, seed=0,
                       collect_speed=50.0, pace_collection=True,
                       n_collectors=n)
        tr = AsyncTrainer(env, ens, algo, rc, mode="threads")
        for w in tr.collectors:
            w.step()                    # 1 warm traj per member
        while tr.data_server.total_pushed < rc.min_warmup_trajs:
            tr.collectors[0].step()     # top up the model's warmup set
        _require(tr.model_worker.step() is not None, "model warmup idled")
        _require(tr.policy_worker.step(), "policy warmup had no model")
        _block(tr.recorder._eval(tr.policy_worker.state["policy"],
                                 jax.random.key(0)))
        # the timed window collects base_trajs MORE on top of warmup
        # (set_target counts pre-pushed trajectories)
        pre = tr.data_server.total_pushed
        tr.run_cfg.total_trajs = pre + base_trajs
        t0 = time.perf_counter()
        tr.run()
        wall = time.perf_counter() - t0
        got = tr.data_server.total_pushed - pre
        _require(got == base_trajs,
                 f"threads fleet criterion not exact ({got})")
        metrics[f"collect_scaling_threads_n{n}_trajs_per_s"] = \
            round(got / wall, 2)

    # -- procs mode: children compile in-run, so the rate is measured
    # over the post-warmup window (first N pushes seen -> last push)
    for n in fleet_sizes:
        env, ens, _algo, _, (pol, acfg) = _build()
        rc = RunConfig(total_trajs=base_trajs + n, seed=0,
                       collect_speed=50.0, pace_collection=True,
                       min_warmup_trajs=4, n_collectors=n,
                       min_final_model_version=1,
                       min_final_policy_version=1)
        tr = AsyncTrainer(env, ens, None, rc, mode="procs",
                          algo_cfg=acfg, pol_cfg=pol)
        done = {}
        th = threading.Thread(target=lambda: done.setdefault("t", tr.run()),
                              daemon=True)
        t_start = time.perf_counter()
        th.start()
        warm = None
        last = None
        seen = 0
        # the poll loop needs its OWN deadline: without one it only
        # exits when the runner thread dies, making the join timeout
        # below unreachable and hanging CI on a wedged fleet child
        while th.is_alive() and time.perf_counter() - t_start < 900:
            srv = getattr(tr, "_proc_servers", None)
            if srv:
                total = srv["data"].total_pushed
                if total > seen:
                    seen = total
                    last = time.perf_counter()
                    if warm is None and total >= n:
                        warm = (last, total)
            time.sleep(0.005)
        th.join(timeout=10)
        _require(not th.is_alive(), "collect_scaling procs run wedged")
        total = tr.proc_info["trajs"]
        _require(total == rc.total_trajs,
                 f"procs fleet criterion not exact ({total})")
        if warm is not None and last is not None and total > warm[1]:
            rate = (total - warm[1]) / max(last - warm[0], 1e-9)
        else:   # run finished between polls: whole-run fallback (incl.
            rate = total / max(time.perf_counter() - t_start, 1e-9)  # compile)
        metrics[f"collect_scaling_procs_n{n}_trajs_per_s"] = round(rate, 2)
    return metrics


def bench_env_farm(metrics, *, batch_sizes=(1, 64, 256),
                   fleet_sizes=(1, 2)):
    """Env-farm scaling (ISSUE 6): each collector simulates B envs per
    step through ONE vmapped rollout (``Env.rollout_batch``) and pushes
    the whole batch at once.

    * threads + procs modes, PACED at 50x robot speed — the same
      methodology as the headline ``threads_trajs_per_s``: a paced
      collector occupies one trajectory's robot time per step however
      many robots it simulates, so a farm of B multiplies the robot-rate
      ceiling by B as long as the batched compute fits inside the pacing
      interval. This is the paper's collection-bound regime (run time ~=
      data-collection time), where the farm is the order-of-magnitude
      lever.
    * ``env_farm_raw_b*``: the UNPACED compute-only rate of the batch
      rollout itself (one collector, learners idle) — the honest
      device-throughput gain from vmapping the scan, reported so the
      paced numbers can't be mistaken for raw compute speedup.

    All metrics are rates (no ``_us`` suffix): never gated, tracked PR
    over PR via the committed baseline and the CI artifact."""
    import threading

    from repro.core import AsyncTrainer, RunConfig, clear_eval_cache
    from repro.core.workers import clear_rollout_cache

    steps_measured = 4          # post-warmup batch steps per collector

    # -- threads mode, paced: B x N grid
    for n in fleet_sizes:
        for b in batch_sizes:
            env, ens, algo, _, _cfgs = _build()
            rc = RunConfig(total_trajs=10 ** 9, seed=0,
                           collect_speed=50.0, pace_collection=True,
                           n_collectors=n, envs_per_collector=b)
            tr = AsyncTrainer(env, ens, algo, rc, mode="threads")
            for w in tr.collectors:
                w.step()            # compiles the B-lane farm program
            while tr.data_server.total_pushed < rc.min_warmup_trajs:
                tr.collectors[0].step(1)
            # one full drain warms the burst ring-write at farm size
            _require(tr.model_worker.step() is not None,
                     "model warmup idled")
            _require(tr.policy_worker.step(), "policy warmup had no model")
            _block(tr.recorder._eval(tr.policy_worker.state["policy"],
                                     jax.random.key(0)))
            pre = tr.data_server.total_pushed
            tr.run_cfg.total_trajs = pre + steps_measured * b * n
            t0 = time.perf_counter()
            tr.run()
            wall = time.perf_counter() - t0
            got = tr.data_server.total_pushed - pre
            _require(got == steps_measured * b * n,
                     f"env-farm threads criterion not exact ({got})")
            metrics[f"env_farm_threads_n{n}_b{b}_trajs_per_s"] = \
                round(got / wall, 2)
    lo = f"env_farm_threads_n1_b{batch_sizes[0]}_trajs_per_s"
    hi = f"env_farm_threads_n1_b{max(batch_sizes)}_trajs_per_s"
    metrics[f"env_farm_threads_b{max(batch_sizes)}_speedup_x"] = \
        round(metrics[hi] / metrics[lo], 1)

    # -- raw compute: unpaced batch rollout, learners idle
    for b in batch_sizes:
        env, ens, algo, _, _cfgs = _build()
        tr = AsyncTrainer(env, ens, algo,
                          RunConfig(total_trajs=8, seed=0,
                                    envs_per_collector=b))
        w = tr.collectors[0]

        def one_batch():
            _require(w.step() is not None, "farm worker had no policy")
            _block(tr.data_server.drain())
        metrics[f"env_farm_raw_b{b}_trajs_per_s"] = round(
            b * 1e6 / _timeit(one_batch, reps=10), 2)

    # rollout programs for every (B) variant + eval programs pile up
    # across the grid above: drop them between groups (the LRU bound
    # also caps them, but the bench should not rely on eviction order)
    clear_rollout_cache()
    clear_eval_cache()

    # -- procs mode, paced: one farm collector per batch size (children
    # compile in-run; rate measured over the post-warmup window, first
    # batch seen -> last push, same protocol as collect_scaling)
    for b in batch_sizes:
        env, ens, _algo, _, (pol, acfg) = _build()
        rc = RunConfig(total_trajs=(steps_measured + 1) * b, seed=0,
                       collect_speed=50.0, pace_collection=True,
                       min_warmup_trajs=4, envs_per_collector=b,
                       min_final_model_version=1,
                       min_final_policy_version=1)
        tr = AsyncTrainer(env, ens, None, rc, mode="procs",
                          algo_cfg=acfg, pol_cfg=pol)
        done = {}
        th = threading.Thread(target=lambda: done.setdefault("t", tr.run()),
                              daemon=True)
        t_start = time.perf_counter()
        th.start()
        warm = None
        last = None
        seen = 0
        while th.is_alive() and time.perf_counter() - t_start < 900:
            srv = getattr(tr, "_proc_servers", None)
            if srv:
                total = srv["data"].total_pushed
                if total > seen:
                    seen = total
                    last = time.perf_counter()
                    if warm is None and total >= b:
                        warm = (last, total)
            time.sleep(0.005)
        th.join(timeout=10)
        _require(not th.is_alive(), "env-farm procs run wedged")
        total = tr.proc_info["trajs"]
        _require(total == rc.total_trajs,
                 f"env-farm procs criterion not exact ({total})")
        if warm is not None and last is not None and total > warm[1]:
            rate = (total - warm[1]) / max(last - warm[0], 1e-9)
        else:   # run finished between polls: whole-run fallback (incl.
            rate = total / max(time.perf_counter() - t_start, 1e-9)  # compile)
        metrics[f"env_farm_procs_b{b}_trajs_per_s"] = round(rate, 2)
    return metrics


def bench_transport(metrics):
    """Transport comparison (PR 9) — measure-only.

    The same parameter pytree pushed and pulled through each transport
    family: in-process is already covered by ``bench_parameter_server``;
    this section adds the posix-shm seqlock and the tcp control plane
    side by side, plus one trajectory claim->push->drain round-trip over
    tcp. Metric names end in ``_usec`` (not ``_us``) deliberately:
    absolute socket latencies swing with the host's network stack, so
    they ride the baseline as tracked numbers and never trip the 20%
    latency gate. The one HARD invariant — an unchanged tcp
    ``pull_if_newer`` moves ZERO array payload bytes (the version word
    rides the frame header) — is ``_require``d here and asserted again
    by tests/test_net.py."""
    import numpy as np

    from repro.core.servers import ShmParameterServer
    from repro.net import ControlPlane

    params = {"w": [np.ones((256, 256), np.float32) for _ in range(4)],
              "b": [np.ones((256,), np.float32) for _ in range(4)]}
    metrics["transport_param_payload_bytes"] = \
        sum(a.nbytes for a in jax.tree.leaves(params))

    # -- posix-shm seqlock (the procs-mode default)
    with ShmParameterServer(params) as shm:
        metrics["transport_shm_push_usec"] = \
            _timeit(lambda: shm.push(params), reps=MICRO_REPS)
        ver = shm.version

        def shm_gated():
            for _ in range(100):
                v, _ = shm.pull_if_newer(ver)
                _require(v is None, "gated shm pull returned a value")
        metrics["transport_shm_pull_unchanged_x100_usec"] = \
            _timeit(shm_gated, reps=MICRO_REPS)

        def shm_changed():
            v, _ = shm.pull_if_newer(ver - 1)   # stale: full copy-out
            _require(v is not None, "stale shm pull returned nothing")
        metrics["transport_shm_pull_changed_usec"] = \
            _timeit(shm_changed, reps=MICRO_REPS)

    # -- tcp control plane (loopback; remote adds wire RTT on top)
    with ControlPlane() as plane:
        ps = plane.parameter_server("bench", template=params)
        metrics["transport_tcp_push_usec"] = \
            _timeit(lambda: ps.push(params), reps=MICRO_REPS)
        ver = ps.version
        before = ps.array_bytes_received

        def tcp_gated():
            for _ in range(100):
                v, _ = ps.pull_if_newer(ver)
                _require(v is None, "gated tcp pull returned a value")
        metrics["transport_tcp_pull_unchanged_x100_usec"] = \
            _timeit(tcp_gated, reps=MICRO_REPS)
        metrics["transport_tcp_unchanged_payload_bytes"] = \
            ps.array_bytes_received - before
        _require(metrics["transport_tcp_unchanged_payload_bytes"] == 0,
                 "unchanged tcp pull moved array bytes over the wire")

        def tcp_changed():
            v, _ = ps.pull_if_newer(ver - 1)    # stale: full wire copy
            _require(v is not None, "stale tcp pull returned nothing")
        metrics["transport_tcp_pull_changed_usec"] = \
            _timeit(tcp_changed, reps=MICRO_REPS)

        ds = plane.data_server(n_collectors=1)
        traj = {"obs": np.ones((15, 3), np.float32),
                "act": np.ones((15, 1), np.float32),
                "rew": np.ones((15,), np.float32)}

        def data_roundtrip():
            _require(ds.try_claim(0, 1) == 1, "tcp claim denied")
            ds.push(traj, collector_id=0)
            _require(len(ds.drain()) == 1, "tcp drain lost the push")
        metrics["transport_tcp_data_roundtrip_usec"] = \
            _timeit(data_roundtrip, reps=MICRO_REPS)
        ps.close()
        ds.close()
    return metrics


def bench_serve(metrics, *, n_requests=12, max_new=16):
    """Serving-tier throughput/latency (ISSUE 8) — measure-only.

    Streams a deterministic mix of prompt lengths through the
    continuous-batching WorldModelServer with one live parameter push
    mid-run. None of these metric names end in ``_us``, so they ride
    ``tools/bench_drift.py``'s noise bands but never the 20% regression
    gate; the ``*_compiles`` counts ARE exact-banded there (a compile
    count has no noise), which pins the compile-once-under-churn
    invariant into the committed artifact.
    """
    import numpy as np
    from repro.configs import get_config
    from repro.core.servers import ParameterServer
    from repro.launch.mesh import make_smoke_mesh
    from repro.models import api as model_api
    from repro.models import lm as LM
    from repro.serve import WorldModelServer

    cfg = get_config("glm4-9b", reduced=True)
    ctx = model_api.shard_ctx(make_smoke_mesh())
    k1, k2 = jax.random.split(jax.random.key(0))
    ps = ParameterServer()
    ps.push(LM.init_params(cfg, ctx, k1))
    srv = WorldModelServer(cfg, param_server=ps, n_slots=4, max_seq=64,
                           page_len=16, prompt_buckets=(16, 32))

    rng = np.random.default_rng(0)
    # warmup: one request per bucket compiles every serve program once
    for b in srv.sched.buckets:
        srv.submit(rng.integers(0, cfg.vocab_size, b), max_new=2)
    srv.run()
    srv.sched.tick_seconds.clear()
    srv.swap_seconds.clear()

    v2 = LM.init_params(cfg, ctx, k2)
    for i in range(n_requests):
        plen = int(rng.integers(4, srv.sched.buckets[-1] + 1))
        srv.submit(rng.integers(0, cfg.vocab_size, plen), max_new=max_new)
        srv.step()
        if i == n_requests // 2:
            ps.push(v2)  # a live training push mid-run
    srv.run()

    st = srv.stats()
    _require(st["decode_compiles"] == 1, "serve decode retraced")
    _require(st["hot_swaps"] == 1, "serve hot-swap not picked up")
    _require(st["tokens_generated"] >= n_requests * max_new,
             "serve dropped tokens")
    metrics["serve_tokens_per_s"] = round(st["tokens_per_s"], 1)
    metrics["serve_p50_ms_per_token"] = round(st["p50_ms_per_token"], 3)
    metrics["serve_p95_ms_per_token"] = round(st["p95_ms_per_token"], 3)
    metrics["serve_hotswap_stall_ms"] = round(st["hotswap_stall_ms"], 3)
    metrics["serve_decode_compiles"] = st["decode_compiles"]
    metrics["serve_prefill_compiles"] = st["prefill_compiles"]
    return metrics


def bench_sharded(metrics):
    """Role-sharded hot path, measured in a SUBPROCESS forced to 8 host
    devices (the parent keeps its single device, so the single-device
    metrics above stay comparable PR over PR). Reports the same
    steady-state latencies for the (1,2,1) role split plus the cost of a
    cross-role parameter movement. New ``sharded_*_us`` metrics are
    informational until they appear in the committed baseline."""
    import os
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    env["PYTHONPATH"] = (str(REPO_ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.hotpath", "--sharded-child"],
        capture_output=True, text=True, env=env, cwd=str(REPO_ROOT),
        timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded child failed (rc={proc.returncode}):\n"
            f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    child = json.loads(proc.stdout.splitlines()[-1])
    metrics.update(child)
    return metrics


def _sharded_child() -> dict:
    """Runs INSIDE the forced-8-device subprocess: build the role-sharded
    workers and time their steady-state steps (same protocol as
    bench_worker_steps). Prints one JSON line on stdout."""
    import jax  # noqa: F811  (re-import after XLA_FLAGS took effect)
    from repro.core import AsyncTrainer
    from repro.core.roles import replicated
    from repro.core.servers import ParameterServer
    env, ens, algo, rc, _cfgs = _build()
    mesh = jax.make_mesh((8,), ("data",))
    tr = AsyncTrainer(env, ens, algo, rc, mesh=mesh, role_ratios=(1, 2, 1))
    _require(not tr.roles.shared, "8-device split must not be degenerate")
    m = {"sharded_devices": 8}

    mw = tr.model_worker
    for _ in range(rc.min_warmup_trajs):
        tr.collector.step()
    mw.step()
    compiles_at_warmup = mw._train_epoch.trace_count
    for _ in range(4):
        tr.collector.step()
        mw.stopper.reset()
        _require(mw.step() is not None, "sharded model worker idled")
    m["sharded_train_epoch_compiles_after_warmup"] = \
        mw._train_epoch.trace_count - compiles_at_warmup

    def one_epoch():
        mw.stopper.reset()
        _require(mw.step() is not None, "sharded model worker idled")
    m["sharded_model_epoch_us"] = _timeit(one_epoch, reps=10)

    pw = tr.policy_worker

    def one_policy_step():
        _require(pw.step(), "sharded policy worker had no model params")
        _block(pw.state["policy"])
    m["sharded_policy_step_us"] = _timeit(one_policy_step, reps=10)

    # cross-role movement: model-mesh params re-placed onto the policy
    # sub-mesh by a version-gated pull (device->device, no host hop).
    # One push outside the timer: a stale version re-pulls the same
    # stored value every rep, so only the device_put is measured
    ps = ParameterServer()
    src, _ = tr.model_server.pull()
    rp = replicated(tr.roles.policy)
    ver = ps.push(src)

    def cross_pull():
        val, _ = ps.pull_if_newer(ver - 1, sharding=rp)
        _require(val is not None, "stale-version pull returned nothing")
        _block(val)
    m["sharded_cross_role_pull_us"] = _timeit(cross_pull, reps=10)

    def gated():
        ver = ps.version
        for _ in range(100):
            v, _ = ps.pull_if_newer(ver, sharding=rp)
            _require(v is None, "gated sharded pull returned a value")
    m["sharded_pull_unchanged_x100_us"] = _timeit(gated, reps=MICRO_REPS)
    return m


def run_bench(*, sharded: bool = False,
              collect_scaling: bool = False,
              env_farm: bool = False,
              serve: bool = False,
              transport: bool = False,
              imagine_fused: bool = False) -> dict:
    metrics = {}
    bench_worker_steps(metrics)
    bench_parameter_server(metrics)
    bench_threads_throughput(metrics)
    bench_procs_throughput(metrics)
    if imagine_fused:
        bench_imagine_fused(metrics)
    if collect_scaling:
        bench_collect_scaling(metrics)
    if env_farm:
        bench_env_farm(metrics)
    if serve:
        bench_serve(metrics)
    if transport:
        bench_transport(metrics)
    if sharded:
        bench_sharded(metrics)
    return {
        "bench": "hotpath",
        "backend": jax.default_backend(),
        "invariants": {
            "no_retrace_after_warmup":
                metrics["train_epoch_compiles_after_warmup"] == 0,
            "unchanged_pull_is_copy_free": True,   # by construction; see
            # ParameterServer.pull_if_newer and tests/test_hotpath.py
        },
        "metrics": metrics,
    }


def check_regression(fresh: dict, baseline: dict):
    """Return list of (metric, old, new, ratio) regressions >20%."""
    regressions = []
    base = baseline.get("metrics", {})
    for k, new in fresh["metrics"].items():
        if not k.endswith("_us"):
            continue
        old = base.get(k)
        if not old:
            continue
        if new > old + max(old * REGRESSION_TOL, JITTER_FLOOR_US):
            regressions.append((k, old, new, round(new / old, 2)))
    if not fresh["invariants"]["no_retrace_after_warmup"]:
        regressions.append(("train_epoch_retraced", 0,
                            fresh["metrics"]
                            ["train_epoch_compiles_after_warmup"], 0))
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on >20%% regression vs the "
                         "committed BENCH_hotpath.json before updating it")
    ap.add_argument("--sharded", action="store_true",
                    help="also measure the role-sharded path in a forced "
                         "8-device subprocess (sharded_*_us metrics)")
    ap.add_argument("--collect-scaling", action="store_true",
                    help="also measure collector-fleet scaling: trajs/s "
                         "at N=1,2,4 in threads and procs modes plus the "
                         "event-mode policy-steps-to-criterion comparison "
                         "(collect_scaling_* metrics, never gated)")
    ap.add_argument("--env-farm", action="store_true",
                    help="also measure env-farm scaling: paced trajs/s "
                         "at B=1,64,256 envs per collector in threads "
                         "(N=1,2) and procs modes, plus the raw unpaced "
                         "batch-rollout rate (env_farm_* metrics, never "
                         "gated)")
    ap.add_argument("--serve", action="store_true",
                    help="also measure the serving tier: continuous-"
                         "batching tokens/s, p50/p95 per-token latency, "
                         "hot-swap stall and compile counts (serve_* "
                         "metrics, never gated)")
    ap.add_argument("--transport", action="store_true",
                    help="also measure the transport seam: shm vs tcp "
                         "push / changed pull / unchanged-pull-x100 and "
                         "a tcp data round-trip (transport_* metrics, "
                         "never gated; the zero-bytes-on-unchanged-pull "
                         "invariant IS hard-required)")
    ap.add_argument("--imagine-fused", action="store_true",
                    help="also measure the fused-imagination speedup: "
                         "the same rollout through the legacy and fused "
                         "step back-to-back (imagine_fused_* metrics; "
                         "the 1.15x speedup floor is hard-required)")
    ap.add_argument("--sharded-child", action="store_true",
                    help=argparse.SUPPRESS)   # internal: see bench_sharded
    ap.add_argument("--out", default=str(BASELINE))
    args = ap.parse_args(argv)

    if args.sharded_child:
        print(json.dumps(_sharded_child()))
        return 0

    fresh = run_bench(sharded=args.sharded,
                      collect_scaling=args.collect_scaling,
                      env_farm=args.env_farm,
                      serve=args.serve,
                      transport=args.transport,
                      imagine_fused=args.imagine_fused)
    for k, v in fresh["metrics"].items():
        print(f"hotpath/{k},{v}")

    out = Path(args.out)
    status = 0
    if args.check and out.exists():
        baseline = json.loads(out.read_text())
        regs = check_regression(fresh, baseline)
        if regs:
            # a loaded machine can blow past 20% on the fast metrics:
            # re-measure once and keep the per-metric best before failing
            print("apparent regression; re-measuring once to rule out "
                  "background load...", file=sys.stderr)
            retry = run_bench(sharded=args.sharded)
            for k, v in retry["metrics"].items():
                old = fresh["metrics"].get(k)
                if k.endswith("_us") and isinstance(old, (int, float)):
                    fresh["metrics"][k] = min(old, v)
            fresh["invariants"]["no_retrace_after_warmup"] = (
                fresh["invariants"]["no_retrace_after_warmup"]
                and retry["invariants"]["no_retrace_after_warmup"])
            regs = check_regression(fresh, baseline)
        if regs:
            for k, old, new, ratio in regs:
                print(f"REGRESSION {k}: {old} -> {new} ({ratio}x)",
                      file=sys.stderr)
            return 1
        print(f"hotpath check ok: no metric regressed "
              f">{int(REGRESSION_TOL * 100)}% vs {out.name}")
        # --check never rewrites the baseline: a lucky quiet-machine run
        # would silently ratchet the bar down for every later run.
        # Re-baseline deliberately by running without --check.
        return status
    if out.exists():
        # re-baselining without the optional sections must not silently
        # drop their committed metrics: carry them over untouched
        skipped = [p for p, ran in (("collect_scaling_",
                                     args.collect_scaling),
                                    ("env_farm_", args.env_farm),
                                    ("serve_", args.serve),
                                    ("transport_", args.transport),
                                    ("imagine_fused_",
                                     args.imagine_fused))
                   if not ran]
        old = json.loads(out.read_text()).get("metrics", {})
        for k, v in old.items():
            if any(k.startswith(p) for p in skipped) \
                    and k not in fresh["metrics"]:
                fresh["metrics"][k] = v
    out.write_text(json.dumps(fresh, indent=1) + "\n")
    print(f"wrote {out}")
    return status


if __name__ == "__main__":
    sys.exit(main())
