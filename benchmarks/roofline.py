"""Roofline analysis from the dry-run's compiled artifacts.

Three terms per (arch x shape x mesh), in seconds per step:

  t_compute    = FLOPs_per_device / 197e12          (v5e bf16 peak)
  t_memory     = HBM_bytes_per_device / 819e9
  t_collective = collective_bytes_per_device / 50e9 (ICI per link)

Collective bytes come from the compiled HLO (parsed + while-loop trip
scaling in repro.launch.dryrun.collective_bytes) — the real artifact.
FLOPs and HBM bytes are ANALYTIC: XLA's cost_analysis() counts while-loop
bodies once (verified experimentally — see EXPERIMENTS.md §Roofline), so
scan-over-layers programs would be undercounted ~L x; the analytic model
below is exact for the dense algebra we emit and is cross-checked against
cost_analysis x trip-count on a no-scan variant.

useful_flop_frac = MODEL_FLOPS / FLOPs_total where MODEL_FLOPS = 6·N·D
(train, dense), 6·N_active·D (MoE) or 2·N_active per decoded token —
the gap exposes remat recompute, attention quadratics and pad-head waste.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s/link

F32, BF16 = 4, 2


def _chips(mesh: str) -> int:
    return 512 if mesh == "2x16x16" else 256


def _cfg(arch: str, shape: str):
    from repro.configs import registry
    return registry.get_config(arch, long_context=(shape == "long_500k"))


def _attn_flops_fwd(cfg, B, S, causal=True) -> float:
    """2 matmuls (qk + av), 2 flops/MAC, causal halves the square."""
    if cfg.family == "ssm":
        return _ssd_flops_fwd(cfg, B, S)
    hd = cfg.hd
    H = cfg.num_heads
    window = cfg.attn_window
    kv_span = min(S, window) if window else S
    per_layer = 2 * 2 * B * S * kv_span * H * hd * (0.5 if causal and
                                                    not window else 1.0)
    n_attn = cfg.num_layers
    if cfg.family == "hybrid":
        n_attn = (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every
        per_layer += 0  # mamba layers counted via _ssd_flops
        return per_layer * n_attn + _ssd_flops_fwd(cfg, B, S)
    if cfg.family == "encdec":
        # decoder self (causal) + cross (full) + encoder self (full)
        enc = 2 * 2 * B * S * S * H * hd
        cross = 2 * 2 * B * S * S * H * hd
        return per_layer * cfg.num_layers + (enc + cross) * cfg.num_layers
    return per_layer * n_attn


def _ssd_flops_fwd(cfg, B, S) -> float:
    """Intra-chunk quadratic + state flops per the SSD algorithm."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    H, P, N, Q = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_chunk
    nc = S // max(Q, 1)
    intra = 2 * B * nc * (Q * Q * H * N + Q * Q * H * P)   # CB^T then ·x
    states = 2 * B * nc * (Q * H * P * N) * 2              # build + apply
    return (intra + states) * cfg.num_layers


def flops_per_step(cfg, shape_kind, B, S, n_params, n_active) -> Dict:
    """Global analytic FLOPs for one step."""
    D = B * S
    if shape_kind == "train":
        base = 6 * n_active * D            # fwd 2ND + bwd 4ND on matmuls
        attn = 3 * _attn_flops_fwd(cfg, B, S)
        remat = 2 * n_active * D + _attn_flops_fwd(cfg, B, S)  # fwd recompute
        total = base + attn + remat
        model = 6 * n_active * D
    elif shape_kind == "prefill":
        total = 2 * n_active * D + _attn_flops_fwd(cfg, B, S)
        model = 2 * n_active * D
    else:  # decode: one token per sequence
        total = 2 * n_active * B
        # attention over the cache
        if cfg.family != "ssm":
            window = cfg.attn_window
            span = min(S, window) if window else S
            n_attn = cfg.num_layers if cfg.family != "hybrid" else \
                (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every
            total += 2 * 2 * B * span * cfg.num_heads * cfg.hd * n_attn
        if cfg.family in ("ssm", "hybrid"):
            total += 2 * B * cfg.ssm_heads * cfg.ssm_head_dim \
                * cfg.ssm_state * 3 * cfg.num_layers
        model = 2 * n_active * B
    return {"total": total, "model": model}


def hbm_bytes_per_device(cfg, shape_kind, B, S, n_params, chips, mesh,
                         num_microbatches) -> float:
    """Analytic per-device HBM traffic per step."""
    tp = 16
    dp = chips // tp
    p_loc = n_params / chips * chips / tp / (1 if True else 1)
    # params are sharded over tp only (dense) — FSDP archs shard more, but
    # use the tp-only bound (conservative upper estimate for them)
    p_loc_bytes = n_params / tp * BF16
    d_tokens_loc = B * S / dp
    d = cfg.d_model
    L = cfg.num_layers
    if shape_kind == "train":
        nm = max(num_microbatches, 1)
        weight_traffic = p_loc_bytes * nm * 3          # fwd + bwd + remat fwd
        opt_traffic = n_params / tp * (F32 * 2 * 2     # m, v read+write
                                       + F32 * 2      # grad read, param rw
                                       + BF16 * 2)
        act_traffic = d_tokens_loc * d * BF16 * L * 12  # ~6 tensors rw
        return weight_traffic + opt_traffic + act_traffic
    if shape_kind == "prefill":
        act = d_tokens_loc * d * BF16 * L * 8
        return p_loc_bytes + act
    # decode
    cache = 0.0
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        span = min(S, cfg.attn_window) if cfg.attn_window else S
        kv = max(cfg.num_kv_heads, 1)
        kv_loc = max(kv / tp, 1) if kv % tp == 0 else kv
        b_loc = max(B / dp, 1)
        cache = b_loc * span * kv_loc * cfg.hd * BF16 * 2 * L
        if cfg.family == "encdec":
            cache *= 2  # cross cache too
    if cfg.family in ("ssm", "hybrid"):
        b_loc = max(B / dp, 1)
        cache += b_loc * cfg.ssm_heads / tp * cfg.ssm_head_dim \
            * cfg.ssm_state * F32 * 2 * L
        if cfg.family == "hybrid":
            span = S
            n_inv = (L + cfg.attn_every - 1) // cfg.attn_every
            cache += (B * span / chips) * cfg.num_kv_heads * cfg.hd \
                * BF16 * 2 * n_inv
    active_loc = 0  # params read once
    return p_loc_bytes + cache


def one_sentence(bottleneck, cfg, shape_kind) -> str:
    if bottleneck == "collective":
        return ("psum traffic dominates: overlap/bucket the reductions, "
                "cast them to bf16, and avoid the conservative psum "
                "transpose (replication-checked shard_map)")
    if bottleneck == "memory":
        if shape_kind == "decode":
            return ("KV/state cache streaming bound: shrink cache dtype "
                    "(int8 KV), shard the cache further, or batch more "
                    "decode requests per weight read")
        return ("weight/activation streaming bound: raise arithmetic "
                "intensity with larger microbatches or fewer remat passes")
    return ("MXU-bound: increase overlap of collectives under compute and "
            "keep matmul dims 128-aligned — already near the good regime")


def roofline_table(records: List[dict]) -> List[dict]:
    from repro.models.config import INPUT_SHAPES
    rows = []
    for r in records:
        if not r.get("ok"):
            rows.append({**r, "ok": False})
            continue
        shape = INPUT_SHAPES[r["shape"]]
        cfg = _cfg(r["arch"], r["shape"])
        chips = _chips(r["mesh"])
        B, S = shape.global_batch, shape.seq_len
        fl = flops_per_step(cfg, shape.kind, B, S, r["params"],
                            r["active_params"])
        t_compute = fl["total"] / chips / PEAK_FLOPS
        hbm = hbm_bytes_per_device(cfg, shape.kind, B, S, r["params"],
                                   chips, r["mesh"],
                                   r.get("num_microbatches", 1))
        t_memory = hbm / HBM_BW
        cc = r.get("collectives", {})
        if "ici_bytes" in cc:
            coll = cc["ici_bytes"]
        else:
            # ring-model approximation from per-type operand totals
            # (records written before the parser gained group awareness;
            # assumes 16-wide groups, exact for this mesh's tp/dp axes)
            g = 16
            coll = (2 * cc.get("all-reduce", 0) * (g - 1) / g
                    + cc.get("all-gather", 0) * (g - 1) / g
                    + cc.get("reduce-scatter", 0) * (g - 1)
                    + cc.get("all-to-all", 0) * (g - 1) / g
                    + cc.get("collective-permute", 0))
        t_coll = coll / ICI_BW
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        bottleneck = max(terms, key=terms.get)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "ok": True,
            "t_compute_ms": t_compute * 1e3,
            "t_memory_ms": t_memory * 1e3,
            "t_collective_ms": t_coll * 1e3,
            "bottleneck": bottleneck,
            "model_flops": fl["model"],
            "hlo_flops_body": r.get("cost_analysis", {}).get("flops"),
            "useful_flop_frac": round(fl["model"] / max(fl["total"], 1), 3),
            "collective_bytes": coll,
            "hbm_bytes_est": hbm,
            "fix_hint": one_sentence(bottleneck, cfg, shape.kind),
        })
    return rows


def main():
    path = Path(__file__).parent.parent / "dryrun_results.json"
    rows = roofline_table(json.loads(path.read_text()))
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'comp_ms':>9s} "
           f"{'mem_ms':>9s} {'coll_ms':>9s} {'bound':>10s} {'useful':>7s}")
    print(hdr)
    for r in rows:
        if not r.get("ok"):
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['t_compute_ms']:9.2f} {r['t_memory_ms']:9.2f} "
              f"{r['t_collective_ms']:9.2f} {r['bottleneck']:>10s} "
              f"{r['useful_flop_frac']:7.3f}")
    out = Path(__file__).parent / "results" / "roofline.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(rows, indent=1))
    print("wrote", out)


if __name__ == "__main__":
    main()
