PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-green test-mesh bench bench-hotpath bench-hotpath-sharded

# Default aggregate = the multi-device mesh suite FIRST, then the tier-1
# verify verbatim from ROADMAP.md. The mesh suite must run as its own
# step: pytest's -x stops at the first of the known pre-existing
# failures (test_arch_smoke/test_dryrun_small), which sort before
# tests/test_mesh.py — relying on collection alone would silently skip
# it. (tests/test_mesh.py itself re-runs tests/_mesh_impl.py in an
# isolated 8-device subprocess: the XLA flag must never leak into an
# already-initialised jax process — device count locks on first use.)
test: test-mesh
	python -m pytest -x -q

# the currently-green suite: everything except the two modules with
# known pre-existing jax-version failures — use this to check a change
test-green:
	python -m pytest -q --ignore=tests/test_arch_smoke.py \
		--ignore=tests/test_dryrun_small.py

# Role-sharded engine suite, run directly against 8 forced host devices
# (faster than the tests/test_mesh.py subprocess wrapper; same tests).
test-mesh:
	XLA_FLAGS="$$XLA_FLAGS --xla_force_host_platform_device_count=8" \
		python -m pytest -q tests/_mesh_impl.py

bench:
	python -m benchmarks.run

# Steady-state hot-path latency gate: re-measures and FAILS if any
# latency metric regressed >20% against the committed BENCH_hotpath.json.
bench-hotpath:
	python -m benchmarks.hotpath --check

# Same gate + the role-sharded measurement (8-device subprocess).
bench-hotpath-sharded:
	python -m benchmarks.hotpath --check --sharded
