PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-green bench bench-hotpath

# tier-1 verify, verbatim from ROADMAP.md (-x stops at the first of the
# known pre-existing failures in test_arch_smoke/test_dryrun_small)
test:
	python -m pytest -x -q

# the currently-green suite: everything except the two modules with
# known pre-existing jax-version failures — use this to check a change
test-green:
	python -m pytest -q --ignore=tests/test_arch_smoke.py \
		--ignore=tests/test_dryrun_small.py

bench:
	python -m benchmarks.run

# Steady-state hot-path latency gate: re-measures and FAILS if any
# latency metric regressed >20% against the committed BENCH_hotpath.json.
bench-hotpath:
	python -m benchmarks.hotpath --check
