PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-mesh test-procs test-kernels lint docs-check bench bench-hotpath bench-hotpath-sharded soak soak-long

# Default aggregate = the multi-device mesh suite FIRST, then the tier-1
# verify verbatim from ROADMAP.md. The mesh suite must run as its own
# step: pytest's -x would otherwise stop before collecting it.
# (tests/test_mesh.py itself re-runs tests/_mesh_impl.py in an isolated
# 8-device subprocess: the XLA flag must never leak into an
# already-initialised jax process — device count locks on first use.)
test: test-mesh
	python -m pytest -x -q

# Role-sharded engine suite, run directly against 8 forced host devices
# (faster than the tests/test_mesh.py subprocess wrapper; same tests).
test-mesh:
	XLA_FLAGS="$$XLA_FLAGS --xla_force_host_platform_device_count=8" \
		python -m pytest -q tests/_mesh_impl.py

# Process-isolated engine suite only (spawned workers, shm servers,
# crash restart) — the slow end-to-end subset of the tier-1 run.
test-procs:
	python -m pytest -q tests/test_procs.py

# Kernel-tier parity sweep through the ops dispatchers: every pallas
# kernel in interpret mode vs its pure-jnp oracle, pinned to CPU (the
# CI `kernels-interpret` step; policy in docs/KERNELS.md).
test-kernels:
	JAX_PLATFORMS=cpu python -m pytest -q tests/test_kernels_interpret.py

# Correctness lint (ruff F/E9 rules, config in pyproject.toml). CI
# installs ruff from requirements-dev.txt; hosts without it fall back to
# the stdlib-only approximation so `make lint` is still meaningful.
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples; \
	else \
		echo "ruff not found (pip install -r requirements-dev.txt);" \
		     "running stdlib fallback linter"; \
		python tools/lint_fallback.py src tests benchmarks examples; \
	fi

# Docs tier gate (PR 9): every relative link and #anchor in README.md +
# docs/*.md must resolve (stdlib only, never fetches the network).
docs-check:
	python tools/docs_check.py

bench:
	python -m benchmarks.run

# Steady-state hot-path latency gate: re-measures and FAILS if any
# latency metric regressed >20% against the committed BENCH_hotpath.json.
bench-hotpath:
	python -m benchmarks.hotpath --check

# Same gate + the role-sharded measurement (8-device subprocess).
bench-hotpath-sharded:
	python -m benchmarks.hotpath --check --sharded

# Chaos soak (PR 7): seeded fault injection (SIGKILLs, stalls, delayed
# respawns across every role) against the procs engine while the
# invariant monitor checks the PR 1-6 contracts live and the resource
# auditor proves zero leaked shm/fds/processes. `soak` = the short PR-CI
# profile (>= 10 faults spanning all three roles, a few minutes);
# `soak-long` = the scheduled-job profile (set SOAK_DURATION=<seconds>
# to keep launching seeded runs for that long). Both write
# SOAK_report.json; see README "Soak & chaos".
soak:
	python -m repro.chaos.soak --profile short --seed 0 \
		--out SOAK_report.json

soak-long:
	python -m repro.chaos.soak --profile long --seed 0 \
		$(if $(SOAK_DURATION),--duration $(SOAK_DURATION)) \
		--out SOAK_report.json
